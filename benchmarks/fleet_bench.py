"""Fleet benchmark: forecaster sweep + hierarchical-fleet scaling suite.

Two suites over the analytic fleet (scheduler + energy model, no token
decode), selected with ``--suite {forecast,hierarchy,all}``:

* ``forecast`` - trace x fleet-size x forecaster sweep. The claim under
  test is the fleet-scale version of the paper's Fig. 4/5 story:
  consulting the placement LUT on a *forecast* of next-slice load
  (proactive migration) beats the paper's reactive lookup on bursty
  traffic.
* ``hierarchy`` - hundreds of engines (512 full / 192 ``--quick``) on an
  overloaded mmpp trace: the flat PR 1 router vs the two-level cell
  router at equal engine count (claim: >= 20 deadline-miss points cut),
  plus an autoscaling scenario whose scale-ups must pay **0** LUT builds
  (warm-start through the shared placement compiler) and a save/load
  warm rerun that rebuilds nothing.

Emits one row per cell plus headline comparisons (same shape as
``benchmarks/paper_tables.py``: (rows, derived)) and writes everything
to ``benchmarks/results/fleet_bench.json``. ``--update-trajectory``
merges the scalar derived values into the committed top-level
``BENCH_fleet.json`` (read-modify-write: suites this invocation did not
run are preserved); ``--gate`` compares the fresh numbers against that
committed point and fails on regression (the CI ``hierarchy-smoke``
job's check).

Run: ``PYTHONPATH=src python -m benchmarks.fleet_bench`` (or
``python benchmarks/fleet_bench.py``). ``--trace [PATH]`` records the
whole sweep through the observability layer (repro.obs) and writes
Perfetto-loadable trace/metrics JSON; ``--flight-recorder [PATH]`` arms
the SLO-breach recorder over the sweep's fleets.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

from repro import api, obs
from repro.fleet import summarize
from repro.fleet.traces import BURSTY, make_trace

SEEDS = (0, 1, 2)
ENGINES = (1, 2)
FORECASTERS = ("none", "ewma", "ar1", "holt")
MARGIN = 1.3                  # over-provisioning factor for forecasters
TOKENS_PER_TASK = 2
N_SLICES = 40

# hierarchy suite shape: full scale vs the CI ``--quick`` scale
HIER_FULL = dict(n_engines=512, n_cells=32, n_slices=48)
HIER_QUICK = dict(n_engines=192, n_cells=16, n_slices=40)
# dag_serving suite shape (heterogeneous cells; engines = cells x per)
DAG_FULL = dict(n_cells=8, engines_per_cell=4, n_slices=48)
DAG_QUICK = dict(n_cells=4, engines_per_cell=2, n_slices=32)
#: per-8-engine DAG arrival rates (a DAG is ~12-26 chunks, so rates sit
#: well below the request-level grid); scaled by n_engines / 8
DAG_TRACE = dict(rate_low=1, rate_high=6, p_down=0.25)
#: committed perf-trajectory point (schema bench-trajectory-v1)
TRAJECTORY = Path(__file__).parent.parent / "BENCH_fleet.json"
#: --gate tolerances vs the committed point (relative); miss rates are
#: compared in absolute points
GATE_REL = {"hier_p99_us": 0.5, "hier_energy_per_token_uj": 0.2}
GATE_MISS_SLACK = 5.0         # absolute points of miss_cut regression

# per-engine rates; scaled by fleet size so offered load per engine is
# constant across fleet sizes
TRACE_GRID: Dict[str, Dict] = {
    "mmpp": dict(rate_low=2, rate_high=12, p_down=0.25),
    "flash": dict(base=2, spike=14, decay=0.75),
    "ramp": dict(start=1, end=12),
    "diurnal": dict(base=2, peak=9),
    "poisson": dict(rate=5),
}
_SCALED = {  # which kwargs scale with engine count
    "mmpp": ("rate_low", "rate_high"),
    "flash": ("base", "spike"),
    "ramp": ("start", "end"),
    "diurnal": ("base", "peak"),
    "poisson": ("rate",),
}


def _cell(trace_name: str, n_engines: int, forecaster: str) -> Dict:
    miss, p95, etok, migr = [], [], [], []
    for seed in SEEDS:
        kw = dict(TRACE_GRID[trace_name])
        for k in _SCALED[trace_name]:
            kw[k] = kw[k] * n_engines
        tr = make_trace(trace_name, n_slices=N_SLICES, seed=seed, **kw)
        fleet = api.fleet(
            "tpu-pool", n_engines=n_engines, forecaster=forecaster,
            tokens_per_task=TOKENS_PER_TASK,
            forecast_margin=1.0 if forecaster == "none" else MARGIN)
        s = summarize(fleet.run(tr))
        miss.append(s.deadline_miss_rate)
        p95.append(s.p95_ms)
        etok.append(s.energy_per_token_uj)
        migr.append(s.migrations)
    return {
        "trace": trace_name,
        "engines": n_engines,
        "forecaster": forecaster,
        "miss_rate": round(float(np.mean(miss)), 4),
        "p95_us": round(float(np.mean(p95)) * 1e3, 3),
        "energy_per_token_uj": round(float(np.mean(etok)), 3),
        "migrating_slices": round(float(np.mean(migr)), 1),
        "seeds": len(SEEDS),
    }


def fleet_sweep() -> Tuple[List[Dict], Dict]:
    rows = [
        _cell(trace, n, fc)
        for trace in TRACE_GRID
        for n in ENGINES
        for fc in FORECASTERS
    ]

    derived: Dict = {}
    wins = {}
    for trace in TRACE_GRID:
        for n in ENGINES:
            cell = {r["forecaster"]: r for r in rows
                    if r["trace"] == trace and r["engines"] == n}
            base = cell["none"]
            best = min((cell[f] for f in FORECASTERS if f != "none"),
                       key=lambda r: r["miss_rate"])
            key = f"{trace}_x{n}"
            derived[f"{key}_miss_none"] = base["miss_rate"]
            derived[f"{key}_miss_best"] = best["miss_rate"]
            derived[f"{key}_best_forecaster"] = best["forecaster"]
            if trace in BURSTY:
                for f in FORECASTERS[1:]:
                    wins.setdefault(f, {})[key] = (
                        cell[f]["miss_rate"] < base["miss_rate"])
    # the headline gate is strict: ONE fixed forecaster must beat the
    # reactive baseline on a majority of bursty cells (a post-hoc
    # best-of-N pick on a single lucky cell would not count)
    majority = {f: sum(w.values()) > len(w) / 2 for f, w in wins.items()}
    derived["forecast_beats_reactive_on_bursty"] = any(majority.values())
    derived["majority_winning_forecasters"] = sorted(
        f for f, ok in majority.items() if ok)
    derived["bursty_wins"] = {
        f: sorted(k for k, v in w.items() if v) for f, w in wins.items()}
    return rows, derived


def _mmpp(n_engines: int, n_slices: int, seed: int = 0):
    kw = dict(TRACE_GRID["mmpp"])
    for k in _SCALED["mmpp"]:
        kw[k] = kw[k] * n_engines
    return make_trace("mmpp", n_slices=n_slices, seed=seed, **kw)


def _hier_row(tag: str, s, wall_s: float, **extra) -> Dict:
    return {
        "scenario": tag,
        "miss_rate": round(s.deadline_miss_rate, 4),
        "p99_us": round(s.p99_ms * 1e3, 3),
        "energy_per_token_uj": round(s.energy_per_token_uj, 3),
        "n_completed": s.n_completed,
        "n_rejected": s.n_rejected,
        "wall_s": round(wall_s, 2),
        **extra,
    }


def hierarchy_sweep(*, n_engines: int, n_cells: int, n_slices: int
                    ) -> Tuple[List[Dict], Dict]:
    """Flat vs two-level router at equal engine count, autoscaling with
    warm-started scale-ups, and a save/load warm rerun."""
    per_cell = n_engines // n_cells
    tr = _mmpp(n_engines, n_slices)
    rows: List[Dict] = []

    t0 = time.perf_counter()
    flat = api.fleet("tpu-pool", n_engines=n_engines, forecaster="ewma",
                     policy="slo", tokens_per_task=TOKENS_PER_TASK,
                     forecast_margin=MARGIN)
    s_flat = summarize(flat.run(tr))
    flat_s = time.perf_counter() - t0
    rows.append(_hier_row("flat_slo_router", s_flat, flat_s,
                          engines=n_engines))

    t0 = time.perf_counter()
    hier = api.hierarchical_fleet(
        "tpu-pool", n_cells=n_cells, engines_per_cell=per_cell,
        forecaster="ewma", forecast_margin=MARGIN,
        tokens_per_task=TOKENS_PER_TASK)
    res = hier.run(tr)
    s_hier = summarize(res)
    hier_s = time.perf_counter() - t0
    rows.append(_hier_row("hierarchical", s_hier, hier_s,
                          engines=n_engines, cells=n_cells))

    # autoscale: start at a quarter of the engines, ceiling = per_cell;
    # every scale-up must come from the warm compiler cache (0 builds)
    pc = api.compiler()
    start_per_cell = max(per_cell // 4, 1)
    t0 = time.perf_counter()
    auto = api.hierarchical_fleet(
        "tpu-pool", n_cells=n_cells, engines_per_cell=start_per_cell,
        forecaster="ewma", forecast_margin=MARGIN,
        tokens_per_task=TOKENS_PER_TASK, autoscale=True,
        max_engines=per_cell, compiler=pc)
    res_auto = auto.run(tr)
    s_auto = summarize(res_auto)
    auto_s = time.perf_counter() - t0
    rows.append(_hier_row(
        "hierarchical_autoscale", s_auto, auto_s,
        engines=res_auto.n_engines_peak, cells=n_cells,
        scale_ups=res_auto.n_scale_ups,
        scale_downs=res_auto.n_scale_downs,
        scale_up_builds=res_auto.scale_up_builds))

    # warm rerun: a restarted fleet loads the LUT cache and rebuilds
    # nothing, scale-ups included
    cache = Path(__file__).parent / "results" / "fleet_bench_luts.json"
    pc.save(cache)
    pc2 = api.compiler()
    pc2.load(cache)
    t0 = time.perf_counter()
    warm = api.hierarchical_fleet(
        "tpu-pool", n_cells=n_cells, engines_per_cell=start_per_cell,
        forecaster="ewma", forecast_margin=MARGIN,
        tokens_per_task=TOKENS_PER_TASK, autoscale=True,
        max_engines=per_cell, compiler=pc2)
    res_warm = warm.run(tr)
    s_warm = summarize(res_warm)
    warm_s = time.perf_counter() - t0
    rows.append(_hier_row(
        "hierarchical_autoscale_warm", s_warm, warm_s,
        engines=res_warm.n_engines_peak, cells=n_cells,
        scale_ups=res_warm.n_scale_ups,
        scale_up_builds=res_warm.scale_up_builds,
        compiler_builds=pc2.n_builds, compiler_loaded=pc2.n_loaded))

    cut = (s_flat.deadline_miss_rate - s_hier.deadline_miss_rate) * 100
    derived = {
        "n_engines": n_engines,
        "n_cells": n_cells,
        "flat_miss": round(s_flat.deadline_miss_rate, 4),
        "hier_miss": round(s_hier.deadline_miss_rate, 4),
        "miss_cut_points": round(cut, 1),
        "miss_cut_ok": cut >= 20.0,
        "flat_p99_us": round(s_flat.p99_ms * 1e3, 3),
        "hier_p99_us": round(s_hier.p99_ms * 1e3, 3),
        "hier_energy_per_token_uj": round(s_hier.energy_per_token_uj, 3),
        "router_speedup": round(flat_s / hier_s, 1) if hier_s > 0 else 0.0,
        "autoscale_scale_ups": res_auto.n_scale_ups,
        "autoscale_peak_engines": res_auto.n_engines_peak,
        "scale_up_builds": res_auto.scale_up_builds,
        "scale_up_builds_ok": (res_auto.n_scale_ups > 0
                               and res_auto.scale_up_builds == 0),
        "warm_compiler_builds": pc2.n_builds,
        "warm_scale_up_builds": res_warm.scale_up_builds,
        "warm_ok": pc2.n_builds == 0 and res_warm.scale_up_builds == 0,
    }
    return rows, derived


def _dag_stats(f, res) -> Dict:
    """DAG-level outcome stats of one run: whole-DAG miss rate (budget =
    class budget x critical path; rejected + unfinished count as
    misses), p95 DAG latency, and energy/token including the per-edge
    handoff tax."""
    from repro.fleet.dag import dag_budget_slices
    T = res.stage_result.t_slice_ns
    n = len(res.completed) + len(res.rejected) + len(res.unfinished)
    miss = len(res.rejected) + len(res.unfinished)
    for d in res.completed:
        b = dag_budget_slices(d, f.router.budget(d.slo_class),
                              f.tenants.get(d.tenant))
        miss += (d.latency_ns / T) > b
    s = summarize(res)
    energy = s.energy_uj + res.handoff_energy_pj / 1e6
    lat = [d.latency_ns / 1e6 for d in res.completed]
    return {
        "n_dags": n,
        "n_rejected": len(res.rejected),
        "miss_rate": miss / n if n else 0.0,
        "p95_us": (float(np.percentile(lat, 95)) * 1e3 if lat else 0.0),
        "energy_per_token_uj": energy / s.tokens if s.tokens else 0.0,
        "handoffs": res.handoffs,
    }


def dag_sweep(*, n_cells: int, engines_per_cell: int, n_slices: int
              ) -> Tuple[List[Dict], Dict]:
    """Stage-level co-scheduling vs request-level routing for the stock
    mixed-tenant registry on bursty mmpp, over capacity-heterogeneous
    cells (mixed variants alternate full/half engine shapes), plus the
    LUT-reuse audit: a DAG fleet must pay ZERO placement builds beyond
    the per-variant set the plain hierarchical fleet pays for the same
    substrates.

    The mixed shapes are the point of the scenario: request-level
    routing pins a whole DAG to its admission cell, so heavy prefill
    stages land on half-capacity cells whenever the full cells are
    queued, while stage-level co-scheduling re-scores every stage and
    keeps heavy stages on full-shape cells and light tool-call /
    draft stages on the half-shape ones."""
    from repro.fleet.dag import dag_arrivals, default_tenants
    n_engines = n_cells * engines_per_cell
    subs = ["tpu-pool-mixed", "gpu-pool-mixed"]
    scale = n_engines / 8
    kw = {k: (v * scale if k in ("rate_low", "rate_high") else v)
          for k, v in DAG_TRACE.items()}

    def run(stage_affinity: bool, seed: int, pc):
        f = api.dag_fleet(
            subs, tenants=default_tenants(), n_cells=n_cells,
            engines_per_cell=engines_per_cell, compiler=pc,
            stage_affinity=stage_affinity, forecaster="ewma",
            forecast_margin=MARGIN, tokens_per_task=TOKENS_PER_TASK,
            admit_headroom=2.0, seed=seed)
        tr = dag_arrivals(f.tenants, n_slices=n_slices, base="mmpp",
                          seed=seed, **kw)
        return f, _dag_stats(f, f.run_dag(tr))

    # LUT-reuse audit against the plain fleet's per-variant build set
    pc_plain = api.compiler()
    api.hierarchical_fleet(subs, n_cells=n_cells,
                           engines_per_cell=engines_per_cell,
                           tokens_per_task=TOKENS_PER_TASK,
                           compiler=pc_plain)
    builds_plain = pc_plain.n_builds
    pc_dag = api.compiler()
    rows: List[Dict] = []
    agg: Dict[str, List[Dict]] = {"stage_level": [], "request_level": []}
    for seed in SEEDS:
        for mode, affinity in (("stage_level", True),
                               ("request_level", False)):
            t0 = time.perf_counter()
            _, st = run(affinity, seed, pc_dag)
            wall = time.perf_counter() - t0
            agg[mode].append(st)
            rows.append({"scenario": mode, "seed": seed,
                         "engines": n_engines, "cells": n_cells,
                         "wall_s": round(wall, 2),
                         **{k: (round(v, 4) if isinstance(v, float)
                                else v) for k, v in st.items()}})
    builds_dag = pc_dag.n_builds

    def mean(mode, key):
        return float(np.mean([s[key] for s in agg[mode]]))

    dag_miss = mean("stage_level", "miss_rate")
    req_miss = mean("request_level", "miss_rate")
    dag_ept = mean("stage_level", "energy_per_token_uj")
    req_ept = mean("request_level", "energy_per_token_uj")
    cut = (req_miss - dag_miss) * 100
    ecut = (req_ept - dag_ept) / req_ept * 100 if req_ept else 0.0
    derived = {
        "n_engines": n_engines,
        "n_cells": n_cells,
        "tenants": ",".join(default_tenants().names()),
        "dag_miss": round(dag_miss, 4),
        "request_miss": round(req_miss, 4),
        "miss_cut_points": round(cut, 1),
        "dag_ept_uj": round(dag_ept, 3),
        "request_ept_uj": round(req_ept, 3),
        "energy_cut_pct": round(ecut, 1),
        "handoffs_stage": int(sum(s["handoffs"]
                                  for s in agg["stage_level"])),
        "handoffs_request": int(sum(s["handoffs"]
                                    for s in agg["request_level"])),
        # the headline claim: stage-level co-scheduling beats
        # request-level routing on miss rate OR energy/token
        "dag_win_ok": cut >= 1.0 or ecut >= 1.0,
        # the reuse claim: zero builds beyond the plain fleet's
        # per-variant set (pinned in tests/test_dag.py too)
        "lut_builds_plain": builds_plain,
        "lut_builds_dag": builds_dag,
        "lut_builds_extra": builds_dag - builds_plain,
        "lut_reuse_ok": builds_dag - builds_plain == 0,
    }
    return rows, derived


def gate_dag_against_trajectory(suite: str, derived: Dict,
                                path: Path = TRAJECTORY) -> List[str]:
    """dag_serving gate: the win + reuse claims must hold, energy must
    stay within GATE_REL tolerance of the committed point, and the
    miss-rate cut must not regress by > GATE_MISS_SLACK points."""
    failures = []
    for flag in ("dag_win_ok", "lut_reuse_ok"):
        if not derived.get(flag):
            failures.append(f"{flag} is false")
    committed = json.loads(path.read_text())["suites"].get(suite)
    if committed is None:
        return failures + [f"no committed suite {suite!r} in {path}"]
    for key in ("dag_ept_uj", "request_ept_uj"):
        ref, got = committed.get(key), derived.get(key)
        if ref and got and abs(got - ref) > 0.2 * ref:
            failures.append(f"{key}: {got} vs committed {ref} "
                            f"(tolerance 20%)")
    ref_cut = committed.get("miss_cut_points")
    if ref_cut is not None and (derived["miss_cut_points"]
                                < ref_cut - GATE_MISS_SLACK):
        failures.append(f"miss_cut_points regressed: "
                        f"{derived['miss_cut_points']} vs committed "
                        f"{ref_cut} (slack {GATE_MISS_SLACK} points)")
    return failures


def merge_trajectory(suite: str, derived: Dict,
                     path: Path = TRAJECTORY) -> None:
    """Read-modify-write the committed trajectory point: update ONE
    suite's scalars, preserve every other suite (benchmarks/run.py owns
    the paper-table suites; this file owns fleet_hierarchy*)."""
    payload = {"schema": "bench-trajectory-v1", "suites": {}}
    if path.exists():
        payload = json.loads(path.read_text())
    payload["suites"][suite] = {
        k: v for k, v in derived.items()
        if isinstance(v, (int, float, bool, str))}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


def gate_against_trajectory(suite: str, derived: Dict,
                            path: Path = TRAJECTORY) -> List[str]:
    """Compare a fresh hierarchy run against the committed point.
    Returns failure messages (empty = pass): the boolean claims must
    hold, p99/energy must stay within GATE_REL of the committed values,
    and the miss-rate cut must not regress by > GATE_MISS_SLACK points."""
    failures = []
    for flag in ("miss_cut_ok", "scale_up_builds_ok", "warm_ok"):
        if not derived.get(flag):
            failures.append(f"{flag} is false")
    committed = json.loads(path.read_text())["suites"].get(suite)
    if committed is None:
        return failures + [f"no committed suite {suite!r} in {path}"]
    for key, rel in GATE_REL.items():
        ref, got = committed.get(key), derived.get(key)
        if ref and got and abs(got - ref) > rel * ref:
            failures.append(f"{key}: {got} vs committed {ref} "
                            f"(tolerance {rel:.0%})")
    ref_cut = committed.get("miss_cut_points")
    if ref_cut is not None and (derived["miss_cut_points"]
                                < ref_cut - GATE_MISS_SLACK):
        failures.append(f"miss_cut_points regressed: "
                        f"{derived['miss_cut_points']} vs committed "
                        f"{ref_cut} (slack {GATE_MISS_SLACK} points)")
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--suite", default="forecast",
                    choices=("forecast", "hierarchy", "dag_serving",
                             "all"))
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized hierarchy suite "
                         f"({HIER_QUICK['n_engines']} engines instead of "
                         f"{HIER_FULL['n_engines']})")
    ap.add_argument("--engines", type=int, default=None,
                    help="override the hierarchy suite's engine count")
    ap.add_argument("--cells", type=int, default=None,
                    help="override the hierarchy suite's cell count")
    ap.add_argument("--update-trajectory", action="store_true",
                    help="merge the hierarchy derived scalars into the "
                         f"committed {TRAJECTORY.name}")
    ap.add_argument("--gate", action="store_true",
                    help="fail (exit 1) when the hierarchy suite's "
                         "claims break or its numbers drift from the "
                         f"committed {TRAJECTORY.name}")
    ap.add_argument("--trace", nargs="?", const="fleet_bench_trace.json",
                    default=None, metavar="PATH",
                    help="record the sweep through repro.obs and write "
                         "Chrome trace-event JSON to PATH (+ metrics.json "
                         "alongside)")
    ap.add_argument("--flight-recorder", nargs="?",
                    const="fleet_bench_flight.json", default=None,
                    metavar="PATH",
                    help="arm the SLO-breach flight recorder over the "
                         "sweep's fleet runs")
    ap.add_argument("--miss-threshold", type=float, default=0.5)
    args = ap.parse_args(argv)

    if args.trace is not None or args.flight_recorder is not None:
        obs.reset()
        rec = None
        if args.flight_recorder is not None:
            rec = obs.FlightRecorder(
                capacity=64, miss_rate_threshold=args.miss_threshold,
                path=args.flight_recorder)
        obs.enable(flight_recorder=rec)

    out_dir = Path(__file__).parent / "results"
    out_dir.mkdir(exist_ok=True)
    payload = {}
    print("name,us_per_call,derived")

    if args.suite in ("forecast", "all"):
        t0 = time.perf_counter()
        rows, derived = fleet_sweep()
        us = (time.perf_counter() - t0) * 1e6
        payload["forecast"] = {"rows": rows, "derived": derived}
        print(f"fleet_sweep,{us:.0f},{json.dumps(derived)}")
        for r in rows:
            print(f"  {r['trace']:8s} x{r['engines']} {r['forecaster']:5s} "
                  f"miss={r['miss_rate']:.3f} p95={r['p95_us']:.2f}us "
                  f"e/tok={r['energy_per_token_uj']:.2f}uJ")

    gate_failures = []
    if args.suite in ("hierarchy", "all"):
        shape = dict(HIER_QUICK if args.quick else HIER_FULL)
        if args.engines is not None:
            shape["n_engines"] = args.engines
        if args.cells is not None:
            shape["n_cells"] = args.cells
        suite_name = ("fleet_hierarchy_quick" if args.quick
                      else "fleet_hierarchy")
        t0 = time.perf_counter()
        rows, derived = hierarchy_sweep(**shape)
        us = (time.perf_counter() - t0) * 1e6
        payload["hierarchy"] = {"rows": rows, "derived": derived}
        print(f"hierarchy_sweep,{us:.0f},{json.dumps(derived)}")
        for r in rows:
            extra = "".join(
                f" {k}={r[k]}" for k in ("scale_ups", "scale_up_builds")
                if k in r)
            print(f"  {r['scenario']:28s} x{r['engines']} "
                  f"miss={r['miss_rate']:.3f} p99={r['p99_us']:.2f}us "
                  f"e/tok={r['energy_per_token_uj']:.2f}uJ "
                  f"wall={r['wall_s']}s{extra}")
        if args.update_trajectory:
            merge_trajectory(suite_name, derived)
            print(f"merged suite {suite_name} into {TRAJECTORY}")
        if args.gate:
            gate_failures = gate_against_trajectory(suite_name, derived)

    if args.suite in ("dag_serving", "all"):
        shape = dict(DAG_QUICK if args.quick else DAG_FULL)
        if args.cells is not None:
            shape["n_cells"] = args.cells
        if args.engines is not None:
            shape["engines_per_cell"] = max(
                args.engines // shape["n_cells"], 1)
        suite_name = ("dag_serving_quick" if args.quick
                      else "dag_serving")
        t0 = time.perf_counter()
        rows, derived = dag_sweep(**shape)
        us = (time.perf_counter() - t0) * 1e6
        payload["dag_serving"] = {"rows": rows, "derived": derived}
        print(f"dag_sweep,{us:.0f},{json.dumps(derived)}")
        for r in rows:
            print(f"  {r['scenario']:14s} seed={r['seed']} "
                  f"miss={r['miss_rate']:.3f} p95={r['p95_us']:.2f}us "
                  f"e/tok={r['energy_per_token_uj']:.2f}uJ "
                  f"handoffs={r['handoffs']}")
        if args.update_trajectory:
            merge_trajectory(suite_name, derived)
            print(f"merged suite {suite_name} into {TRAJECTORY}")
        if args.gate:
            gate_failures += gate_dag_against_trajectory(suite_name,
                                                         derived)

    with open(out_dir / "fleet_bench.json", "w") as f:
        json.dump(payload, f, indent=2)
    if args.trace is not None:
        paths = obs.export(
            trace_path=args.trace,
            metrics_path=Path(args.trace).with_name("metrics.json"))
        print(f"wrote {paths['trace']} ({len(obs.tracer())} events) "
              f"and {paths['metrics']}")
    rec = obs.flight_recorder()
    if rec is not None:
        print(f"flight-recorder: {rec.n_dumps} dump(s), "
              f"{len(rec)} frames buffered")
    if gate_failures:
        for msg in gate_failures:
            print(f"GATE FAILED {msg}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
