"""Fleet benchmark: trace x fleet-size x forecaster sweep.

Runs the analytic fleet (scheduler + energy model, no token decode) over
bursty and steady arrival traces, several fleet sizes and every
forecaster, averaging each cell over seeds. Emits one row per cell plus
headline comparisons (same shape as ``benchmarks/paper_tables.py``:
(rows, derived)), and writes everything to
``benchmarks/results/fleet_bench.json``.

The claim under test is the fleet-scale version of the paper's Fig. 4/5
story: consulting the placement LUT on a *forecast* of next-slice load
(proactive migration) beats the paper's reactive lookup on bursty
traffic - lower deadline-miss-rate at a modest energy-per-token premium.

Run: ``PYTHONPATH=src python -m benchmarks.fleet_bench`` (or
``python benchmarks/fleet_bench.py``). ``--trace [PATH]`` records the
whole sweep through the observability layer (repro.obs) and writes
Perfetto-loadable trace/metrics JSON; ``--flight-recorder [PATH]`` arms
the SLO-breach recorder over the sweep's fleets.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

from repro import api, obs
from repro.fleet import summarize
from repro.fleet.traces import BURSTY, make_trace

SEEDS = (0, 1, 2)
ENGINES = (1, 2)
FORECASTERS = ("none", "ewma", "ar1", "holt")
MARGIN = 1.3                  # over-provisioning factor for forecasters
TOKENS_PER_TASK = 2
N_SLICES = 40

# per-engine rates; scaled by fleet size so offered load per engine is
# constant across fleet sizes
TRACE_GRID: Dict[str, Dict] = {
    "mmpp": dict(rate_low=2, rate_high=12, p_down=0.25),
    "flash": dict(base=2, spike=14, decay=0.75),
    "ramp": dict(start=1, end=12),
    "diurnal": dict(base=2, peak=9),
    "poisson": dict(rate=5),
}
_SCALED = {  # which kwargs scale with engine count
    "mmpp": ("rate_low", "rate_high"),
    "flash": ("base", "spike"),
    "ramp": ("start", "end"),
    "diurnal": ("base", "peak"),
    "poisson": ("rate",),
}


def _cell(trace_name: str, n_engines: int, forecaster: str) -> Dict:
    miss, p95, etok, migr = [], [], [], []
    for seed in SEEDS:
        kw = dict(TRACE_GRID[trace_name])
        for k in _SCALED[trace_name]:
            kw[k] = kw[k] * n_engines
        tr = make_trace(trace_name, n_slices=N_SLICES, seed=seed, **kw)
        fleet = api.fleet(
            "tpu-pool", n_engines=n_engines, forecaster=forecaster,
            tokens_per_task=TOKENS_PER_TASK,
            forecast_margin=1.0 if forecaster == "none" else MARGIN)
        s = summarize(fleet.run(tr))
        miss.append(s.deadline_miss_rate)
        p95.append(s.p95_ms)
        etok.append(s.energy_per_token_uj)
        migr.append(s.migrations)
    return {
        "trace": trace_name,
        "engines": n_engines,
        "forecaster": forecaster,
        "miss_rate": round(float(np.mean(miss)), 4),
        "p95_us": round(float(np.mean(p95)) * 1e3, 3),
        "energy_per_token_uj": round(float(np.mean(etok)), 3),
        "migrating_slices": round(float(np.mean(migr)), 1),
        "seeds": len(SEEDS),
    }


def fleet_sweep() -> Tuple[List[Dict], Dict]:
    rows = [
        _cell(trace, n, fc)
        for trace in TRACE_GRID
        for n in ENGINES
        for fc in FORECASTERS
    ]

    derived: Dict = {}
    wins = {}
    for trace in TRACE_GRID:
        for n in ENGINES:
            cell = {r["forecaster"]: r for r in rows
                    if r["trace"] == trace and r["engines"] == n}
            base = cell["none"]
            best = min((cell[f] for f in FORECASTERS if f != "none"),
                       key=lambda r: r["miss_rate"])
            key = f"{trace}_x{n}"
            derived[f"{key}_miss_none"] = base["miss_rate"]
            derived[f"{key}_miss_best"] = best["miss_rate"]
            derived[f"{key}_best_forecaster"] = best["forecaster"]
            if trace in BURSTY:
                for f in FORECASTERS[1:]:
                    wins.setdefault(f, {})[key] = (
                        cell[f]["miss_rate"] < base["miss_rate"])
    # the headline gate is strict: ONE fixed forecaster must beat the
    # reactive baseline on a majority of bursty cells (a post-hoc
    # best-of-N pick on a single lucky cell would not count)
    majority = {f: sum(w.values()) > len(w) / 2 for f, w in wins.items()}
    derived["forecast_beats_reactive_on_bursty"] = any(majority.values())
    derived["majority_winning_forecasters"] = sorted(
        f for f, ok in majority.items() if ok)
    derived["bursty_wins"] = {
        f: sorted(k for k, v in w.items() if v) for f, w in wins.items()}
    return rows, derived


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", nargs="?", const="fleet_bench_trace.json",
                    default=None, metavar="PATH",
                    help="record the sweep through repro.obs and write "
                         "Chrome trace-event JSON to PATH (+ metrics.json "
                         "alongside)")
    ap.add_argument("--flight-recorder", nargs="?",
                    const="fleet_bench_flight.json", default=None,
                    metavar="PATH",
                    help="arm the SLO-breach flight recorder over the "
                         "sweep's fleet runs")
    ap.add_argument("--miss-threshold", type=float, default=0.5)
    args = ap.parse_args(argv)

    if args.trace is not None or args.flight_recorder is not None:
        obs.reset()
        rec = None
        if args.flight_recorder is not None:
            rec = obs.FlightRecorder(
                capacity=64, miss_rate_threshold=args.miss_threshold,
                path=args.flight_recorder)
        obs.enable(flight_recorder=rec)

    out_dir = Path(__file__).parent / "results"
    out_dir.mkdir(exist_ok=True)
    t0 = time.perf_counter()
    rows, derived = fleet_sweep()
    us = (time.perf_counter() - t0) * 1e6
    with open(out_dir / "fleet_bench.json", "w") as f:
        json.dump({"rows": rows, "derived": derived}, f, indent=2)
    print("name,us_per_call,derived")
    print(f"fleet_sweep,{us:.0f},{json.dumps(derived)}")
    for r in rows:
        print(f"  {r['trace']:8s} x{r['engines']} {r['forecaster']:5s} "
              f"miss={r['miss_rate']:.3f} p95={r['p95_us']:.2f}us "
              f"e/tok={r['energy_per_token_uj']:.2f}uJ")
    if args.trace is not None:
        paths = obs.export(
            trace_path=args.trace,
            metrics_path=Path(args.trace).with_name("metrics.json"))
        print(f"wrote {paths['trace']} ({len(obs.tracer())} events) "
              f"and {paths['metrics']}")
    rec = obs.flight_recorder()
    if rec is not None:
        print(f"flight-recorder: {rec.n_dumps} dump(s), "
              f"{len(rec)} frames buffered")


if __name__ == "__main__":
    main()
