"""Benchmarks reproducing the paper's tables/figures.

One function per table/figure; each returns (rows, derived) where rows are
CSV-ready dicts and derived holds the headline numbers compared against the
paper's claims. ``benchmarks.run`` aggregates. All stacks are constructed
through the ``repro.api`` facade (substrate/solver registries).
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro import api
from repro.core import spaces as sp
from repro.core import workloads
from repro.core.system import (default_t_slice_ns, energy_savings_table,
                               run_hh_pim)

RHO = 4.0   # benchmark default weight-reuse factor (DESIGN.md SS.2)
HHPIM = api.substrate("edge-hhpim")

PAPER_PEAK_MS = {          # SS.IV.B: SRAM+MRAM peak / MRAM-only peak per inf.
    "efficientnet_b0": (3.106, 4.450),
    "mobilenet_v2": (2.571, 3.684),
    "resnet_18": (32.087, 45.974),
}

PAPER_FIG5_CASE1 = {"baseline": 86.23, "hetero": 78.7, "hybrid": 66.5}
PAPER_FIG5_CASE2 = {"baseline": 41.46, "hetero": 3.72, "hybrid": 39.69}
PAPER_AVG = {"baseline": 60.43, "hetero": 36.3, "hybrid": 48.58}
PAPER_TABLE6 = {   # ES vs (baseline, hetero, hybrid)
    "case3_periodic_spike": (72.01, 55.78, 54.09),
    "case4_periodic_spike_frequent": (61.46, 38.38, 47.60),
    "case5_pulsing": (48.94, 16.89, 42.10),
    "case6_random": (59.28, 34.14, 50.52),
}
PAPER_FIG6_OPT_SAVING = 43.17


def table3_latency() -> Tuple[List[Dict], Dict]:
    """Table III + SS.IV.B: model peak-performance inference times."""
    rows, derived = [], {}
    for rho in (1.0, RHO):
        for m in sp.TINYML_MODELS.values():
            em = HHPIM.energy_model(m, rho=rho)
            t_s = em.task_cost(em.peak_placement(True)).t_task_ns / 1e6
            t_m = em.task_cost(em.peak_placement(False)).t_task_ns / 1e6
            ps, pm = PAPER_PEAK_MS[m.name]
            rows.append({"model": m.name, "rho": rho,
                         "peak_sram_ms": round(t_s, 3),
                         "paper_sram_ms": ps,
                         "peak_mram_ms": round(t_m, 3),
                         "paper_mram_ms": pm,
                         "sram_dev_pct": round(100 * (t_s / ps - 1), 1),
                         "mram_dev_pct": round(100 * (t_m / pm - 1), 1)})
            if rho == 1.0:
                derived[f"{m.name}_sram_dev_pct"] = rows[-1]["sram_dev_pct"]
    # qualitative claim: SRAM peak beats MRAM peak everywhere
    derived["sram_faster_than_mram_everywhere"] = all(
        r["peak_sram_ms"] < r["peak_mram_ms"] for r in rows)
    return rows, derived


def table5_power() -> Tuple[List[Dict], Dict]:
    """Table V: per-op dynamic energy + per-slice static by space."""
    m = sp.EFFICIENTNET_B0
    em = HHPIM.energy_model(m, rho=RHO)
    rows = []
    for s in HHPIM.arch.spaces:
        rows.append({
            "space": s.name,
            "op_ns": round(s.op_ns(RHO), 3),
            "op_pj": round(s.op_pj(RHO), 1),
            "static_mw_total": round(s.static_mw_total, 2),
            "weight_time_ns": round(em.weight_time_ns(s), 2),
            "weight_energy_pj": round(em.weight_energy_pj(s), 1),
        })
    derived = {"lp_sram_cheapest_dynamic":
               min(rows, key=lambda r: r["op_pj"])["space"] == "lp_sram",
               "lp_mram_cheapest_static":
               min(rows, key=lambda r: r["static_mw_total"])["space"]
               == "lp_mram"}
    return rows, derived


def fig6_placement_sweep() -> Tuple[List[Dict], Dict]:
    """Fig. 6: memory utilization + E_task across t_constraint."""
    m = sp.EFFICIENTNET_B0
    T = default_t_slice_ns(m, RHO)
    lut = api.lut("edge-hhpim", m, t_slice_ns=T, n_points=64, rho=RHO)
    em = HHPIM.energy_model(m, rho=RHO)
    peak = em.peak_placement(True)
    rows = []
    seq = []
    for e in lut.entries:
        if not e.feasible:
            continue
        used = tuple(sorted(k for k, v in e.placement.items() if v > 0))
        if not seq or seq[-1] != used:
            seq.append(used)
        # unoptimized reference: keep the peak placement at this window
        tc = em.task_cost(peak)
        e_unopt = tc.e_dyn_task_pj + em.static_energy_pj(
            peak, e.t_constraint_ns, tc.t_cluster_ns)
        rows.append({"t_constraint_ms": round(e.t_constraint_ns / 1e6, 3),
                     **{k: e.placement.get(k, 0) for k in
                        ("hp_mram", "hp_sram", "lp_mram", "lp_sram")},
                     "e_task_uj": round(e.e_task_pj * 1e-6, 1),
                     "e_unopt_uj": round(e_unopt * 1e-6, 1)})
    last = rows[-1]
    opt_saving = 100 * (1 - last["e_task_uj"] / last["e_unopt_uj"])
    derived = {
        "placement_sequence": " -> ".join("+".join(u) for u in seq),
        "relaxed_region_saving_pct": round(opt_saving, 2),
        "paper_claim_pct": PAPER_FIG6_OPT_SAVING,
        "ends_lp_mram_only": last["lp_mram"] == m.n_params,
    }
    return rows, derived


def fig5_energy_savings() -> Tuple[List[Dict], Dict]:
    """Fig. 5: savings vs 3 comparison PIMs across 6 scenarios x 3 models."""
    rows = []
    avgs = {"baseline": [], "hetero": [], "hybrid": []}
    for m in sp.TINYML_MODELS.values():
        tab = energy_savings_table(m, rho=RHO, lut_points=48)
        for scen, r in tab.items():
            rows.append({"model": m.name, "scenario": scen,
                         "vs_baseline_pct": round(r["baseline"], 2),
                         "vs_hetero_pct": round(r["hetero"], 2),
                         "vs_hybrid_pct": round(r["hybrid"], 2)})
            for k in avgs:
                avgs[k].append(r[k])
    case1 = [r for r in rows if r["scenario"] == "case1_low_constant"]
    derived = {
        "avg_vs_baseline_pct": round(float(np.mean(avgs["baseline"])), 2),
        "avg_vs_hetero_pct": round(float(np.mean(avgs["hetero"])), 2),
        "avg_vs_hybrid_pct": round(float(np.mean(avgs["hybrid"])), 2),
        "paper_avg": PAPER_AVG,
        "best_case1_vs_baseline": max(r["vs_baseline_pct"] for r in case1),
        "paper_case1": PAPER_FIG5_CASE1,
        "positive_everywhere": all(r["vs_baseline_pct"] > 0
                                   and r["vs_hetero_pct"] > 0
                                   and r["vs_hybrid_pct"] > 0
                                   for r in rows),
    }
    return rows, derived


def table6_cases() -> Tuple[List[Dict], Dict]:
    """Table VI: Cases 3-6 energy savings (model = ResNet-18, the paper's
    highest-savings benchmark)."""
    tab = energy_savings_table(sp.RESNET_18, rho=RHO, lut_points=48)
    rows = []
    dev = []
    for scen, paper in PAPER_TABLE6.items():
        r = tab[scen]
        ours = (r["baseline"], r["hetero"], r["hybrid"])
        rows.append({"scenario": scen,
                     "vs_baseline_pct": round(ours[0], 2),
                     "vs_hetero_pct": round(ours[1], 2),
                     "vs_hybrid_pct": round(ours[2], 2),
                     "paper_baseline": paper[0],
                     "paper_hetero": paper[1], "paper_hybrid": paper[2]})
        dev.extend(abs(a - b) for a, b in zip(ours, paper))
    derived = {"mean_abs_dev_pp": round(float(np.mean(dev)), 2),
               "max_abs_dev_pp": round(float(np.max(dev)), 2)}
    return rows, derived


def fig4_scheduler_latency() -> Tuple[List[Dict], Dict]:
    """Fig. 4 scenarios through the runtime: deadline adherence (<= 2T)."""
    rows = []
    misses = 0
    for m in (sp.EFFICIENTNET_B0,):
        for scen in workloads.SCENARIOS:
            res = run_hh_pim(m, scen, rho=RHO, lut_points=48)
            moved = sum(r.moved_weights for r in res.reports)
            rows.append({"model": m.name, "scenario": scen,
                         "energy_uj": round(res.energy_uj, 1),
                         "deadline_misses": res.deadline_miss,
                         "weights_moved": moved})
            misses += res.deadline_miss
    return rows, {"total_deadline_misses": misses}


def solver_agreement() -> Tuple[List[Dict], Dict]:
    """Registry cross-check: the verbatim Algorithm 1+2 DP and the
    closed-form solver, selected by name through the facade, must agree on
    the six workload cases (same deadline behaviour, close energy)."""
    m = sp.EFFICIENTNET_B0
    rows = []
    devs = []
    for scen in workloads.SCENARIOS:
        res = {}
        for solver in ("closed-form", "dp"):
            t0 = time.perf_counter()
            res[solver] = run_hh_pim(m, scen, rho=RHO, lut_points=24,
                                     solver=solver)
            res[solver + "_s"] = time.perf_counter() - t0
        cf, dp = res["closed-form"], res["dp"]
        dev = 100 * (dp.energy_uj / cf.energy_uj - 1)
        devs.append(abs(dev))
        rows.append({"scenario": scen,
                     "closed_form_uj": round(cf.energy_uj, 1),
                     "dp_uj": round(dp.energy_uj, 1),
                     "energy_dev_pct": round(dev, 3),
                     "cf_misses": cf.deadline_miss,
                     "dp_misses": dp.deadline_miss,
                     "cf_build_s": round(res["closed-form_s"], 3),
                     "dp_build_s": round(res["dp_s"], 3)})
    misses_agree = all(r["cf_misses"] == r["dp_misses"] for r in rows)
    derived = {"max_energy_dev_pct": round(float(np.max(devs)), 3),
               "misses_agree": misses_agree,
               "agreement_ok": bool(misses_agree and float(np.max(devs))
                                    <= SOLVER_AGREEMENT_TOL_PCT)}
    return rows, derived


# dp's tick quantization + LUT-grid path dependence budget, shared by the
# solver_agreement table (edge) and the pool_substrates gpu check and
# gated in CI (benchmarks/run.py --gate).
SOLVER_AGREEMENT_TOL_PCT = 10.0


def pool_substrates() -> Tuple[List[Dict], Dict]:
    """gpu-pool vs tpu-pool across the six workload cases under each
    substrate's own slice protocol (scheduler runs, closed-form solver),
    plus the dp/closed-form cross-check on the gpu backend - the registry
    analogue of Fig. 5 for the serving pools."""
    subs = {name: api.substrate(name, tokens_per_task=2)
            for name in ("tpu-pool", "gpu-pool")}
    ctx = {}
    for name, sub in subs.items():
        model = sub.model_spec()
        ctx[name] = (sub, model, sub.default_t_slice_ns(model))
    rows = []
    gpu_cf = {}         # scenario -> (energy_pj, misses), reused below
    for scen, loads in workloads.SCENARIOS.items():
        row: Dict = {"scenario": scen}
        for name, (sub, model, T) in ctx.items():
            sched = api.scheduler(sub, model, t_slice_ns=T, lut_points=24)
            reports = sched.run(loads)
            key = name.split("-")[0]
            e_pj = sum(r.energy_pj for r in reports)
            misses = sum(not r.deadline_met for r in reports)
            row[f"{key}_uj"] = round(e_pj * 1e-6, 1)
            row[f"{key}_misses"] = misses
            row[f"{key}_migrating_slices"] = sum(r.moved_weights > 0
                                                 for r in reports)
            if name == "gpu-pool":
                gpu_cf[scen] = (e_pj, misses)
        row["gpu_over_tpu"] = round(row["gpu_uj"] / row["tpu_uj"], 3)
        rows.append(row)

    # gpu dp vs closed-form cross-check: same cases, closed-form totals
    # reused from above, one dp LUT shared by all scenarios
    sub, model, T = ctx["gpu-pool"]
    dp_lut = sub.build_lut(model, t_slice_ns=T, n_points=24, solver="dp")
    devs = []
    misses_agree = True
    for scen, loads in workloads.SCENARIOS.items():
        sched = api.scheduler(sub, model, t_slice_ns=T, lut_points=24,
                              solver="dp", lut=dp_lut)
        reports = sched.run(loads)
        dp = (sum(r.energy_pj for r in reports),
              sum(not r.deadline_met for r in reports))
        cf = gpu_cf[scen]
        devs.append(abs(100 * (dp[0] / cf[0] - 1)))
        misses_agree &= cf[1] == dp[1]

    derived = {
        "mean_gpu_over_tpu": round(float(np.mean(
            [r["gpu_over_tpu"] for r in rows])), 3),
        "misses_match_tpu": all(r["gpu_misses"] == r["tpu_misses"]
                                for r in rows),
        # false is EXPECTED, not a bug: the pools are different machines
        # (own t_slice sizing, static-energy window, DVFS-scaled LP
        # clock), so per-scenario deadline outcomes need not coincide -
        # only each pool's own dp/closed-form cross-check is gated
        "misses_match_tpu_reason": (
            "informational; gpu-pool and tpu-pool each run their own "
            "t_slice/static-window/DVFS operating point, so deadline "
            "outcomes can legitimately diverge per scenario"),
        "gpu_dp_max_dev_pct": round(float(np.max(devs)), 3),
        "gpu_dp_misses_agree": misses_agree,
        "gpu_solver_agreement_ok": bool(
            misses_agree
            and float(np.max(devs)) <= SOLVER_AGREEMENT_TOL_PCT),
    }
    return rows, derived


def multipool() -> Tuple[List[Dict], Dict]:
    """K-pool combine cross-check: the three-pool ``cxl-tier-3``
    substrate (HBM / node-DDR / CXL far pool) run through the scheduler
    on the six workload cases, once per solver method - the K=3
    exercise of the min-plus multi-cluster combine (DESIGN.md SS.7).
    Gated in CI like the gpu pool check: identical deadline behaviour
    and energy within the shared solver tolerance."""
    sub = api.substrate("cxl-tier-3", tokens_per_task=2)
    model = sub.model_spec()
    T = sub.default_t_slice_ns(model)
    luts = {s: sub.build_lut(model, t_slice_ns=T, n_points=24, solver=s)
            for s in ("closed-form", "dp")}
    rows, devs = [], []
    misses_agree = True
    for scen, loads in workloads.SCENARIOS.items():
        res = {}
        for solver, lut in luts.items():
            t0 = time.perf_counter()
            sched = api.scheduler(sub, model, t_slice_ns=T, lut_points=24,
                                  solver=solver, lut=lut)
            reports = sched.run(loads)
            res[solver] = (sum(r.energy_pj for r in reports),
                           sum(not r.deadline_met for r in reports),
                           sum(r.moved_weights > 0 for r in reports),
                           time.perf_counter() - t0)
        cf, dp = res["closed-form"], res["dp"]
        dev = 100 * (dp[0] / cf[0] - 1)
        devs.append(abs(dev))
        misses_agree &= cf[1] == dp[1]
        rows.append({"scenario": scen,
                     "closed_form_uj": round(cf[0] * 1e-6, 1),
                     "dp_uj": round(dp[0] * 1e-6, 1),
                     "energy_dev_pct": round(dev, 3),
                     "cf_misses": cf[1], "dp_misses": dp[1],
                     "cf_migrating_slices": cf[2],
                     "dp_migrating_slices": dp[2],
                     "cf_run_s": round(cf[3], 3),
                     "dp_run_s": round(dp[3], 3)})
    n_clusters = len(sub.arch.clusters)
    derived = {
        "n_clusters": n_clusters,
        "max_energy_dev_pct": round(float(np.max(devs)), 3),
        "misses_agree": misses_agree,
        "cxl3_solver_agreement_ok": bool(
            misses_agree and n_clusters == 3
            and float(np.max(devs)) <= SOLVER_AGREEMENT_TOL_PCT),
    }
    return rows, derived


def lut_build() -> Tuple[List[Dict], Dict]:
    """Placement-compiler throughput: batched vs per-point LUT builds.

    The first entry in the repo's bench trajectory. Per substrate and
    solver method, builds the LUT at the substrate's default resolution
    through the batched driver and through the per-point loop (same
    bytes out - the equivalence suite asserts it) and records points/sec
    plus the batch-vs-loop speedup. The closed-form speedup is the CI
    gate (``speedup_ok``: >= 1x on any machine; the acceptance target is
    >= 3x, recorded as ``closed_form_speedup_3x``). The dp rows are
    informational - their cost is dominated by the shared kernel-op
    table build, so batching the combine step is near-neutral. The
    fleet row records the PlacementCompiler's cross-fleet cache win: a
    second bring-up on the same shapes (restarted or scaled-out fleet
    sharing one compiler) is served from cache, where pre-compiler every
    ``api.fleet`` call rebuilt its shape LUTs from scratch (shape dedup
    *within* one fleet predates the compiler and is not claimed here -
    ``fleet_bringup_builds`` just confirms it still holds: 2 builds for
    8 mixed engines).

    The ``fused`` column records which engine produced the batched
    build: ``host`` for the closed-form/loop paths, the resolved
    :mod:`repro.kernels.lut_pipeline` backend for dp. The clock-grid
    row is the fused-pipeline headline (second gate, recorded to
    ``BENCH_lut.json``): the cxl-tier-3 three-pool substrate across its
    DVFS clock grid, solved by ONE fused launch (``build_lut_grid``)
    vs one per-point host fold per variant -
    ``fused_speedup_cxl3_clockgrid``, gated >= 1x and drift-checked
    against the committed point."""
    from repro.core import placement

    def _time(fn, repeats: int) -> float:
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    rows = []
    cf_speedups = {}
    for name, method, repeats in (("edge-hhpim", "closed_form", 3),
                                  ("gpu-pool", "closed_form", 3),
                                  ("edge-hhpim", "dp", 1),
                                  ("gpu-pool", "dp", 1)):
        sub = (api.substrate(name, rho=RHO) if name.startswith("edge")
               else api.substrate(name, tokens_per_task=2))
        model = sub.model_spec()
        em = sub.energy_model(model)
        T = sub.default_t_slice_ns(model)
        kw = dict(t_slice_ns=T, n_points=sub.lut_points, rho=em.rho, em=em,
                  method=method, static_window=sub.static_window)
        if method == "dp":       # warm the fused-op jit cache off-clock
            placement.build_lut(sub.arch, model, **kw)
        built = placement.build_lut(sub.arch, model, batched=True, **kw)
        t_batched = _time(lambda: placement.build_lut(
            sub.arch, model, batched=True, **kw), repeats)
        t_loop = _time(lambda: placement.build_lut(
            sub.arch, model, batched=False, **kw), repeats)
        speedup = t_loop / t_batched
        if method == "closed_form":
            cf_speedups[name] = speedup
        rows.append({"substrate": name, "method": method,
                     "n_points": sub.lut_points,
                     "loop_ms": round(t_loop * 1e3, 3),
                     "batched_ms": round(t_batched * 1e3, 3),
                     "speedup": round(speedup, 2),
                     "points_per_sec": round(sub.lut_points / t_batched),
                     "fused": built.backend or "host"})

    # fleet bring-up: cold = first compile of 8 mixed engines (2 distinct
    # shapes -> 2 builds); warm = a second fleet on the same compiler,
    # served entirely from cache (0 builds)
    sub = api.substrate("gpu-pool-mixed", tokens_per_task=2)
    variants = [sub.engine_variant(i) for i in range(8)]
    model = sub.model_spec()
    T = sub.default_t_slice_ns(model)
    pc = api.compiler()
    t_cold = _time(lambda: pc.compile(variants, model, t_slice_ns=T), 1)
    cold_builds = pc.stats()["builds"]
    t_warm = _time(lambda: pc.compile(variants, model, t_slice_ns=T), 1)
    rows.append({"substrate": "gpu-pool-mixed[8]",
                 "method": "compiler-rebringup",
                 "n_points": sub.lut_points,
                 "loop_ms": round(t_cold * 1e3, 3),
                 "batched_ms": round(t_warm * 1e3, 3),
                 "speedup": round(t_cold / t_warm, 2),
                 "points_per_sec": round(8 * sub.lut_points / t_warm),
                 "fused": "host"})
    rebringup_speedup = rows[-1]["speedup"]

    # fused clock-grid build (DESIGN.md SS.6/SS.10): every DVFS clock
    # point of the three-pool substrate solved in one fused launch vs
    # one per-point host fold loop per variant (same bytes out -
    # tests/test_lut_pipeline.py asserts it)
    sub = api.substrate("cxl-tier-3")
    t_slice = sub.default_t_slice_ns()
    clocks = list(sub.tech_model().clock_grid(3))
    ems = [sub.with_clock(c).energy_model() for c in clocks]
    kw = dict(t_slice_ns=t_slice, n_points=sub.lut_points, method="dp",
              k_groups=64, dp_ticks=512, static_window=sub.static_window)
    grid = placement.build_lut_grid(ems, **kw)      # warm the jit cache
    fused_backend = grid[0].backend or "host"
    t_fused = _time(lambda: placement.build_lut_grid(ems, **kw), 2)

    def _host_loop():
        for em in ems:
            placement.build_lut(em.arch, em.model, em=em, batched=False,
                                **kw)

    t_hloop = _time(_host_loop, 1)
    fused_speedup = t_hloop / t_fused
    rows.append({"substrate": f"cxl-tier-3[{len(clocks)}clk]",
                 "method": "dp-clock-grid",
                 "n_points": sub.lut_points,
                 "loop_ms": round(t_hloop * 1e3, 3),
                 "batched_ms": round(t_fused * 1e3, 3),
                 "speedup": round(fused_speedup, 2),
                 "points_per_sec": round(
                     len(clocks) * sub.lut_points / t_fused),
                 "fused": fused_backend})

    min_cf = min(cf_speedups.values())
    derived = {
        "closed_form_speedup_edge": round(cf_speedups["edge-hhpim"], 2),
        "closed_form_speedup_gpu": round(cf_speedups["gpu-pool"], 2),
        "batched_points_per_sec_edge": rows[0]["points_per_sec"],
        "fleet_rebringup_speedup": rebringup_speedup,
        "fleet_bringup_builds": cold_builds,
        "fleet_warm_builds": pc.stats()["builds"] - cold_builds,
        "fused_speedup_cxl3_clockgrid": round(fused_speedup, 2),
        "fused_backend": fused_backend,
        "fused_ok": bool(fused_speedup >= 1.0),
        "speedup_ok": bool(min_cf >= 1.0 and fused_speedup >= 1.0),
        "closed_form_speedup_3x": bool(min_cf >= 3.0),
    }
    return rows, derived


def obs_overhead() -> Tuple[List[Dict], Dict]:
    """Observability overhead on the fleet hot loop (DESIGN.md SS.8).

    The GATED number is the disabled-mode cost - what every production
    run pays for having the instrumentation compiled in: each site is
    one ``obs.enabled()`` predicate. We count how many guard calls one
    fleet run executes (a counting stub that still returns False, so
    the run stays uninstrumented), microbenchmark the guard, and gate
    the projected overhead vs the disabled run at <= 5%.

    The fully-enabled cost (spans + counters + flight recorder) is
    recorded as ``tracer_overhead_pct`` for the trajectory but not
    gated: on this *analytic* fleet a slice is ~100 us of numpy, a
    near-worst case for relative tracing cost; enable tracing to
    diagnose, not during perf sweeps.
    """
    from repro import obs
    from repro.fleet import make_trace, summarize

    REPS, N_SLICES, ENGINES = 3, 40, 2
    pc = api.compiler()
    trace = make_trace("mmpp", n_slices=N_SLICES, seed=0,
                       rate_low=2 * ENGINES, rate_high=12 * ENGINES)

    def one_run() -> float:
        fleet = api.fleet("tpu-pool", n_engines=ENGINES,
                          forecaster="ewma", compiler=pc)
        t0 = time.perf_counter()
        summarize(fleet.run(trace))
        return (time.perf_counter() - t0) * 1e3

    obs.reset()
    one_run()                               # warm-up: LUT build + caches
    base_ms = min(one_run() for _ in range(REPS))

    # disabled-mode guard accounting: count predicates, price one
    n_guards = 0
    real_enabled = obs.enabled

    def counting_enabled() -> bool:
        nonlocal n_guards
        n_guards += 1
        return False

    obs.enabled = counting_enabled
    try:
        one_run()
    finally:
        obs.enabled = real_enabled
    N = 100_000
    t0 = time.perf_counter()
    for _ in range(N):
        real_enabled()
    guard_ns = (time.perf_counter() - t0) / N * 1e9
    disabled_pct = 100.0 * (n_guards * guard_ns / 1e6) / base_ms

    obs.enable(flight_recorder=obs.FlightRecorder(
        capacity=32, miss_rate_threshold=2.0))   # record, never dump
    traced_ms = min(one_run() for _ in range(REPS))
    n_events = len(obs.tracer())
    obs.reset()

    rows = [{"mode": "disabled", "ms": round(base_ms, 3)},
            {"mode": "traced", "ms": round(traced_ms, 3)}]
    derived = {
        "baseline_ms": round(base_ms, 3),
        "traced_ms": round(traced_ms, 3),
        "tracer_overhead_pct": round(
            100.0 * (traced_ms - base_ms) / base_ms, 2),
        "trace_events": n_events,
        "guard_calls_per_run": n_guards,
        "guard_ns": round(guard_ns, 1),
        "disabled_overhead_pct": round(disabled_pct, 3),
        "overhead_ok": bool(disabled_pct <= 5.0),
    }
    return rows, derived


def dvfs_frontier() -> Tuple[List[Dict], Dict]:
    """Online DVFS controller vs every static clock point (DESIGN SS.10).

    Runs the same bursty mmpp trace through a gpu-pool fleet once per
    static ``lp_clock`` grid point (the TechModel's 5-point DVFS grid
    plus the substrate default - exactly the grid the controller solves
    over) and once with the online controller
    (``api.fleet(dvfs=True)``), which picks the energy-minimal
    (placement, clock) pair per slice under the slice latency budget.

    The GATED claim (``frontier_ok``): the controller's energy/token is
    strictly below the best static grid point's at equal-or-better
    deadline-miss rate. ``dominates_all_points`` records the stronger
    per-point Pareto dominance (true on this trace: a static clock
    either burns leakage waiting out the low phases or burns switching
    energy through the bursts; the controller does neither).
    """
    from repro.fleet import make_trace, summarize

    ENGINES, SLICES = 2, 40
    sub = api.substrate("gpu-pool")
    grid = sub.tech_model().clock_grid(5, include=(sub.lp_clock,))
    # mostly-feasible load with real low-traffic phases: burstiness the
    # controller can exploit, not a standing overload that pins every
    # run at max clock
    trace = make_trace("mmpp", n_slices=SLICES, seed=0,
                       rate_low=1 * ENGINES, rate_high=8 * ENGINES,
                       p_up=0.1, p_down=0.35)
    pc = api.compiler()

    def run(dvfs=None, lp_clock=None):
        over = {} if lp_clock is None else {"lp_clock": lp_clock}
        fleet = api.fleet("gpu-pool", n_engines=ENGINES, compiler=pc,
                          dvfs=dvfs, **over)
        s = summarize(fleet.run(trace))
        clocks = [r.clock for w in fleet.workers
                  for r in w.reports if r.clock is not None]
        return s, clocks

    rows, static = [], {}
    for c in grid:
        s, _ = run(lp_clock=c)
        static[c] = s
        rows.append({"mode": "static", "clock": round(c, 4),
                     "miss_rate": round(s.deadline_miss_rate, 4),
                     "energy_per_token_uj":
                         round(s.energy_per_token_uj, 4),
                     "p99_us": round(s.p99_ms * 1e3, 3)})
    ctrl, clocks = run(dvfs=True)
    rows.append({"mode": "controller", "clock": None,
                 "miss_rate": round(ctrl.deadline_miss_rate, 4),
                 "energy_per_token_uj":
                     round(ctrl.energy_per_token_uj, 4),
                 "p99_us": round(ctrl.p99_ms * 1e3, 3)})

    best_c = min(static, key=lambda c: static[c].energy_per_token_uj)
    best = static[best_c]
    eps = 1e-9
    frontier_ok = (
        ctrl.energy_per_token_uj < best.energy_per_token_uj
        and ctrl.deadline_miss_rate <= best.deadline_miss_rate + eps)
    dominates_all = all(
        ctrl.energy_per_token_uj < s.energy_per_token_uj
        and ctrl.deadline_miss_rate <= s.deadline_miss_rate + eps
        for s in static.values())
    derived = {
        "n_grid_points": len(grid),
        "ctrl_energy_per_token_uj": round(ctrl.energy_per_token_uj, 4),
        "ctrl_miss_rate": round(ctrl.deadline_miss_rate, 4),
        "ctrl_mean_clock": round(sum(clocks) / len(clocks), 4),
        "best_static_clock": round(best_c, 4),
        "best_static_energy_per_token_uj":
            round(best.energy_per_token_uj, 4),
        "best_static_miss_rate": round(best.deadline_miss_rate, 4),
        "ept_saving_pct": round(
            100.0 * (1 - ctrl.energy_per_token_uj
                     / best.energy_per_token_uj), 2),
        "frontier_ok": bool(frontier_ok),
        "dominates_all_points": bool(dominates_all),
    }
    return rows, derived


ALL = {
    "table3_latency": table3_latency,
    "table5_power": table5_power,
    "fig6_placement_sweep": fig6_placement_sweep,
    "fig5_energy_savings": fig5_energy_savings,
    "table6_cases": table6_cases,
    "fig4_scheduler_latency": fig4_scheduler_latency,
    "solver_agreement": solver_agreement,
    "pool_substrates": pool_substrates,
    "multipool": multipool,
    "lut_build": lut_build,
    "obs_overhead": obs_overhead,
    "dvfs_frontier": dvfs_frontier,
}
