"""Benchmark aggregator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract, where
``us_per_call`` is the wall time of producing the table and ``derived``
holds the headline numbers compared to the paper's claims. Row-level detail
is written to benchmarks/results/<name>.csv. The tables construct their
stacks through ``repro.api`` (see benchmarks/paper_tables.py); ``--only``
filters by table-name substring.
"""
from __future__ import annotations

import argparse
import csv
import json
import time
from pathlib import Path

from benchmarks import paper_tables


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only tables whose name contains this")
    args = ap.parse_args()
    out_dir = Path(__file__).parent / "results"
    out_dir.mkdir(exist_ok=True)
    print("name,us_per_call,derived")
    for name, fn in paper_tables.ALL.items():
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        rows, derived = fn()
        us = (time.perf_counter() - t0) * 1e6
        if rows:
            with open(out_dir / f"{name}.csv", "w", newline="") as f:
                w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
                w.writeheader()
                w.writerows(rows)
        print(f"{name},{us:.0f},{json.dumps(derived)}")


if __name__ == "__main__":
    main()
