"""Benchmark aggregator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract, where
``us_per_call`` is the wall time of producing the table and ``derived``
holds the headline numbers compared to the paper's claims. Row-level detail
is written to benchmarks/results/<name>.csv. The tables construct their
stacks through ``repro.api`` (see benchmarks/paper_tables.py); ``--only``
filters by table-name substring.

CI runs ``--quick`` (the cheap subset below), writes the derived numbers to
a JSON artifact with ``--json``, and turns the solver cross-checks into
required checks with ``--gate`` (exit 1 when a gated table's
``agreement_ok`` / ``*_solver_agreement_ok`` flag is false).
"""
from __future__ import annotations

import argparse
import csv
import json
import sys
import time
from pathlib import Path

from benchmarks import paper_tables

# cheap-enough-for-every-PR subset: the per-space constants table, the
# three solver cross-checks (edge dp-vs-closed-form, gpu-vs-tpu pools,
# the 3-pool cxl-tier-3 min-plus combine), the placement-compiler
# throughput suite, the observability-overhead check and the online
# DVFS controller frontier
QUICK = ("table5_power", "solver_agreement", "pool_substrates",
         "multipool", "lut_build", "obs_overhead", "dvfs_frontier")

# name -> (flag inside the table's derived dict that must be true)
GATES = {
    "solver_agreement": "agreement_ok",
    "pool_substrates": "gpu_solver_agreement_ok",
    "multipool": "cxl3_solver_agreement_ok",
    "lut_build": "speedup_ok",
    "obs_overhead": "overhead_ok",
    "dvfs_frontier": "frontier_ok",
}


def write_trajectory(derived_all: dict, path: Path) -> None:
    """The stable perf-trajectory point: suite -> scalar metrics only
    (committed as a top-level BENCH_fleet.json so future PRs diff their
    numbers against this baseline). Merge semantics: only the suites
    this invocation ran are replaced - suites owned by other runners
    (benchmarks/fleet_bench.py's fleet_hierarchy*) and suites skipped by
    ``--only``/``--quick`` are preserved. Non-scalar derived values
    (lists, per-cell dicts) are dropped - the schema must stay
    diffable."""
    payload = {"schema": "bench-trajectory-v1", "suites": {}}
    if path.exists():
        payload = json.loads(path.read_text())
    for suite, derived in derived_all.items():
        scalars = {k: v for k, v in derived.items()
                   if isinstance(v, (int, float, bool, str))}
        if scalars:
            payload["suites"][suite] = scalars
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only tables whose name contains this")
    ap.add_argument("--quick", action="store_true",
                    help=f"run only the CI subset {QUICK}")
    ap.add_argument("--json", default=None,
                    help="write all derived numbers to this path as JSON")
    ap.add_argument("--gate", action="append", default=None,
                    choices=sorted(GATES),
                    help="fail (exit 1) unless this table's agreement "
                         "flag is true; repeatable")
    args = ap.parse_args()
    out_dir = Path(__file__).parent / "results"
    out_dir.mkdir(exist_ok=True)
    derived_all = {}
    print("name,us_per_call,derived")
    for name, fn in paper_tables.ALL.items():
        if args.only and args.only not in name:
            continue
        if args.quick and name not in QUICK:
            continue
        t0 = time.perf_counter()
        rows, derived = fn()
        us = (time.perf_counter() - t0) * 1e6
        derived_all[name] = derived
        if rows:
            with open(out_dir / f"{name}.csv", "w", newline="") as f:
                w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
                w.writeheader()
                w.writerows(rows)
        print(f"{name},{us:.0f},{json.dumps(derived)}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(derived_all, f, indent=2)
        print(f"wrote {args.json}", file=sys.stderr)
        traj = Path(__file__).parent.parent / "BENCH_fleet.json"
        write_trajectory(derived_all, traj)
        print(f"wrote {traj}", file=sys.stderr)
    failed = []
    for gate in args.gate or ():
        if gate not in derived_all:
            failed.append(f"{gate}: gated table did not run")
        elif not derived_all[gate].get(GATES[gate]):
            failed.append(f"{gate}: {GATES[gate]} is false "
                          f"({json.dumps(derived_all[gate])})")
    if failed:
        for msg in failed:
            print(f"GATE FAILED {msg}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
