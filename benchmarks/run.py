"""Benchmark aggregator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract, where
``us_per_call`` is the wall time of producing the table and ``derived``
holds the headline numbers compared to the paper's claims. Row-level detail
is written to benchmarks/results/<name>.csv. The tables construct their
stacks through ``repro.api`` (see benchmarks/paper_tables.py); ``--only``
filters by table-name substring.

CI runs ``--quick`` (the cheap subset below), writes the derived numbers to
a JSON artifact with ``--json``, and turns the solver cross-checks into
required checks with ``--gate`` (exit 1 when a gated table's
``agreement_ok`` / ``*_solver_agreement_ok`` flag is false).
"""
from __future__ import annotations

import argparse
import csv
import json
import sys
import time
from pathlib import Path

from benchmarks import paper_tables

# cheap-enough-for-every-PR subset: the per-space constants table, the
# three solver cross-checks (edge dp-vs-closed-form, gpu-vs-tpu pools,
# the 3-pool cxl-tier-3 min-plus combine), the placement-compiler
# throughput suite, the observability-overhead check and the online
# DVFS controller frontier
QUICK = ("table5_power", "solver_agreement", "pool_substrates",
         "multipool", "lut_build", "obs_overhead", "dvfs_frontier")

# name -> (flag inside the table's derived dict that must be true)
GATES = {
    "solver_agreement": "agreement_ok",
    "pool_substrates": "gpu_solver_agreement_ok",
    "multipool": "cxl3_solver_agreement_ok",
    "lut_build": "speedup_ok",
    "obs_overhead": "overhead_ok",
    "dvfs_frontier": "frontier_ok",
}

# suites whose trajectory point lives outside BENCH_fleet.json: the
# placement/LUT-build suite owns BENCH_lut.json (the fused-pipeline
# speedup trajectory); everything else stays in the fleet file
TRAJECTORY_ROUTES = {"lut_build": "BENCH_lut.json"}
DEFAULT_TRAJECTORY = "BENCH_fleet.json"

#: lut_build drift gate: the fresh fused clock-grid speedup must stay
#: above this fraction of the committed BENCH_lut.json point. Timing on
#: shared CI runners is noisy, so the slack is wide - the gate exists
#: to catch the fused path silently degrading to per-point host folds
#: (which costs ~10x), not 20% jitter.
LUT_DRIFT_FRACTION = 0.25


def gate_lut_drift(derived: dict, path: Path) -> list:
    """Failure messages for the lut_build drift gate (empty = pass)."""
    if not path.exists():
        return [f"lut_build: no committed {path.name} to gate against"]
    committed = json.loads(path.read_text())["suites"].get("lut_build", {})
    ref = committed.get("fused_speedup_cxl3_clockgrid")
    got = derived.get("fused_speedup_cxl3_clockgrid")
    if not got:
        return ["lut_build: fused_speedup_cxl3_clockgrid missing"]
    if ref and got < ref * LUT_DRIFT_FRACTION:
        return [f"lut_build: fused clock-grid speedup drifted: {got} vs "
                f"committed {ref} (floor {LUT_DRIFT_FRACTION:.0%})"]
    return []


def write_trajectory(derived_all: dict, path: Path) -> None:
    """The stable perf-trajectory point: suite -> scalar metrics only
    (committed as a top-level BENCH_fleet.json so future PRs diff their
    numbers against this baseline). Merge semantics: only the suites
    this invocation ran are replaced - suites owned by other runners
    (benchmarks/fleet_bench.py's fleet_hierarchy*) and suites skipped by
    ``--only``/``--quick`` are preserved. Non-scalar derived values
    (lists, per-cell dicts) are dropped - the schema must stay
    diffable."""
    payload = {"schema": "bench-trajectory-v1", "suites": {}}
    if path.exists():
        payload = json.loads(path.read_text())
    for suite, derived in derived_all.items():
        scalars = {k: v for k, v in derived.items()
                   if isinstance(v, (int, float, bool, str))}
        if scalars:
            payload["suites"][suite] = scalars
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only tables whose name contains this")
    ap.add_argument("--quick", action="store_true",
                    help=f"run only the CI subset {QUICK}")
    ap.add_argument("--json", default=None,
                    help="write all derived numbers to this path as JSON")
    ap.add_argument("--gate", action="append", default=None,
                    choices=sorted(GATES),
                    help="fail (exit 1) unless this table's agreement "
                         "flag is true; repeatable")
    args = ap.parse_args()
    out_dir = Path(__file__).parent / "results"
    out_dir.mkdir(exist_ok=True)
    derived_all = {}
    print("name,us_per_call,derived")
    for name, fn in paper_tables.ALL.items():
        if args.only and args.only not in name:
            continue
        if args.quick and name not in QUICK:
            continue
        t0 = time.perf_counter()
        rows, derived = fn()
        us = (time.perf_counter() - t0) * 1e6
        derived_all[name] = derived
        if rows:
            with open(out_dir / f"{name}.csv", "w", newline="") as f:
                w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
                w.writeheader()
                w.writerows(rows)
        print(f"{name},{us:.0f},{json.dumps(derived)}")
    repo_root = Path(__file__).parent.parent
    if args.json:
        with open(args.json, "w") as f:
            json.dump(derived_all, f, indent=2)
        print(f"wrote {args.json}", file=sys.stderr)
        # fan the trajectory points out to their owning files (merge
        # semantics per file: suites not run here are preserved)
        by_file: dict = {}
        for suite, derived in derived_all.items():
            fname = TRAJECTORY_ROUTES.get(suite, DEFAULT_TRAJECTORY)
            by_file.setdefault(fname, {})[suite] = derived
        for fname, suites in by_file.items():
            traj = repo_root / fname
            write_trajectory(suites, traj)
            print(f"wrote {traj}", file=sys.stderr)
    failed = []
    for gate in args.gate or ():
        if gate not in derived_all:
            failed.append(f"{gate}: gated table did not run")
        elif not derived_all[gate].get(GATES[gate]):
            failed.append(f"{gate}: {GATES[gate]} is false "
                          f"({json.dumps(derived_all[gate])})")
        elif gate == "lut_build":
            failed.extend(gate_lut_drift(
                derived_all[gate],
                repo_root / TRAJECTORY_ROUTES["lut_build"]))
    if failed:
        for msg in failed:
            print(f"GATE FAILED {msg}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
