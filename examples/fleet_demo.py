"""Fleet serving demo: reactive vs forecasting placement on bursty traffic.

Everything is constructed through the ``repro.api`` facade: a substrate
registry name ("tpu-pool" / "tpu-pool-mixed") plus keyword overrides.
The demo builds a two-engine fleet (analytic path - no model weights
needed), runs the same diurnal trace with the paper's reactive LUT
lookup and with a trend-aware forecaster feeding the scheduler's
``lookup_tasks`` hook, shows a heterogeneous (mixed big/small) fleet
where SLO-aware routing beats round-robin, and finishes with the
two-level hierarchical fleet (``api.hierarchical_fleet``) autoscaling
through a burst at zero LUT-build cost.

Run: PYTHONPATH=src python examples/fleet_demo.py
"""
from repro import api
from repro.fleet import make_trace, summarize


def show(tag, s):
    print(f"  {tag:28s} miss={s.deadline_miss_rate:.3f} "
          f"p95={s.p95_ms * 1e3:.2f}us "
          f"energy/token={s.energy_per_token_uj:.2f}uJ "
          f"migrating_slices={s.migrations}")


def main():
    trace = make_trace("diurnal", n_slices=48, seed=0, base=4, peak=18)
    print(f"trace: {trace.name}, {trace.total} requests, "
          f"peak {trace.peak}/slice")

    print("reactive vs proactive (2 engines, slo routing):")
    for fc in ("none", "holt"):
        fleet = api.fleet("tpu-pool", n_engines=2, forecaster=fc,
                          forecast_margin=1.0 if fc == "none" else 1.3)
        show(f"forecaster={fc}", summarize(fleet.run(trace)))

    print("routing policy on a mixed (big+small) fleet:")
    for policy in ("round_robin", "slo"):
        fleet = api.fleet("tpu-pool-mixed", n_engines=2, forecaster="holt",
                          policy=policy, forecast_margin=1.3)
        show(f"policy={policy}", summarize(fleet.run(trace)))

    print("admission control (queue cap 12 tasks/engine):")
    fleet = api.fleet("tpu-pool", n_engines=2, forecaster="holt",
                      forecast_margin=1.3, admission_limit=12)
    show("admission_limit=12", summarize(fleet.run(trace)))

    print("hierarchical fleet (4 cells, autoscaling, warm scale-ups):")
    pc = api.compiler()
    hier = api.hierarchical_fleet("tpu-pool", n_cells=4,
                                  engines_per_cell=1, autoscale=True,
                                  max_engines=4, compiler=pc)
    res = hier.run(trace)
    show("cells=4 autoscale", summarize(res))
    print(f"  engines {res.n_engines_start} -> peak {res.n_engines_peak} "
          f"-> end {res.n_engines_end}; {res.n_scale_ups} scale-ups paid "
          f"{res.scale_up_builds} LUT builds "
          f"(compiler: {pc.n_builds} builds, {pc.n_hits} hits)")


if __name__ == "__main__":
    main()
