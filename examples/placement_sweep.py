"""Fig. 6 sweep for all three TinyML benchmarks + rho sensitivity.

Shows how the optimal placement and E_task evolve with t_constraint for
EfficientNet-B0 / MobileNetV2 / ResNet-18, and how the weight-reuse factor
rho moves the LP-MRAM-only crossover (DESIGN.md SS.2 modeling note).

Run:  PYTHONPATH=src python examples/placement_sweep.py
"""
from repro import api
from repro.core import spaces as sp
from repro.core.system import default_t_slice_ns


def sweep(model: sp.ModelSpec, rho: float) -> None:
    T = default_t_slice_ns(model, rho)
    lut = api.lut("edge-hhpim", model, t_slice_ns=T, n_points=32, rho=rho)
    print(f"-- {model.name} (rho={rho}, T={T/1e6:.2f} ms)")
    seen = None
    for e in lut.entries:
        if not e.feasible:
            continue
        key = tuple(sorted(k for k, v in e.placement.items() if v))
        if key != seen:
            seen = key
            share = {k: f"{100*v/model.n_params:.0f}%"
                     for k, v in e.placement.items() if v}
            print(f"   t_c >= {e.t_constraint_ns/1e6:7.2f} ms  "
                  f"E_task {e.e_task_pj*1e-6:9.1f} uJ  {share}")


def main() -> None:
    for model in sp.TINYML_MODELS.values():
        sweep(model, rho=4.0)
        print()
    print("== rho sensitivity (EfficientNet-B0): the LP-MRAM-only regime "
          "appears once weight fetches amortize over >=2 MACs ==")
    for rho in (1.0, 2.0, 4.0, 16.0):
        sweep(sp.EFFICIENTNET_B0, rho)
        print()


if __name__ == "__main__":
    main()
