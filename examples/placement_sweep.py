"""Fig. 6 sweep for all three TinyML benchmarks + rho sensitivity, plus
the gpu-pool DVFS frontier.

Shows how the optimal placement and E_task evolve with t_constraint for
EfficientNet-B0 / MobileNetV2 / ResNet-18, how the weight-reuse factor
rho moves the LP-MRAM-only crossover (DESIGN.md SS.2 modeling note), and
how the ``gpu-pool`` substrate's LP-pool frequency scale (``lp_clock``,
DESIGN.md SS.5) traces the paper's energy-vs-latency frontier on the GPU
backend: a slower LP pool stretches the achievable per-task latency while
the relaxed-deadline energy drops.

Run:  PYTHONPATH=src python examples/placement_sweep.py
"""
from repro import api
from repro.core import spaces as sp
from repro.core.system import default_t_slice_ns


def sweep(model: sp.ModelSpec, rho: float) -> None:
    T = default_t_slice_ns(model, rho)
    lut = api.lut("edge-hhpim", model, t_slice_ns=T, n_points=32, rho=rho)
    print(f"-- {model.name} (rho={rho}, T={T/1e6:.2f} ms)")
    seen = None
    for e in lut.entries:
        if not e.feasible:
            continue
        key = tuple(sorted(k for k, v in e.placement.items() if v))
        if key != seen:
            seen = key
            share = {k: f"{100*v/model.n_params:.0f}%"
                     for k, v in e.placement.items() if v}
            print(f"   t_c >= {e.t_constraint_ns/1e6:7.2f} ms  "
                  f"E_task {e.e_task_pj*1e-6:9.1f} uJ  {share}")


def dvfs_frontier(n_clocks: int = 5, tokens_per_task: int = 2) -> None:
    """2-D (clock x placement) energy-latency frontier of the gpu-pool
    substrate (DESIGN.md SS.10).

    Axis 1 is the DVFS clock grid of the substrate's TechModel (the same
    grid the online controller solves over); axis 2 is the placement LUT
    at each grid point, batch-built through one PlacementCompiler pass.
    The per-clock rows show the 1-D frontier a static ``lp_clock`` pin
    reaches; the solved frontier below them is what the controller picks
    per latency budget - the lower envelope over both axes, with the
    chosen clock printed wherever the winning (clock, placement) pair
    changes."""
    sub = api.substrate("gpu-pool", tokens_per_task=tokens_per_task)
    tm = sub.tech_model()
    grid = tm.clock_grid(n_clocks, include=(sub.lp_clock,))
    model = sub.model_spec()
    T = sub.default_t_slice_ns(model)
    pc = api.compiler()
    luts = pc.compile_clock_grid(sub, clocks=grid, t_slice_ns=T,
                                 n_points=24)
    print(f"== gpu-pool 2-D DVFS frontier: {len(grid)}-point TechModel "
          f"grid [{tm.dvfs_min:.2f}, {tm.dvfs_max:.2f}] x placement ==")
    for clock, lut in luts.items():
        feasible = [e for e in lut.entries if e.feasible]
        peak, relaxed = feasible[0], feasible[-1]
        print(f"   lp_clock {clock:4.2f}  t_peak {peak.t_task_ns:8.2f} ns  "
              f"E_peak {peak.e_task_pj:10.1f} pJ  "
              f"E_relaxed {relaxed.e_task_pj:10.1f} pJ")
    print("   -- solved (placement, clock) per latency budget "
          "(the online controller's lower envelope) --")
    t_lo = min(e.t_task_ns for lut in luts.values()
               for e in lut.entries if e.feasible)
    seen = None
    for i in range(25):
        budget = t_lo + (T - t_lo) * i / 24
        best = None
        for clock, lut in luts.items():
            e = lut.lookup(budget)
            if not e.feasible or e.t_task_ns > budget:
                continue
            if best is None or e.e_task_pj < best[1].e_task_pj:
                best = (clock, e)
        if best is None:
            continue
        clock, e = best
        key = (clock,
               tuple(sorted(k for k, v in e.placement.items() if v)))
        if key == seen:
            continue
        seen = key
        share = {k: f"{100 * v / model.n_params:.0f}%"
                 for k, v in e.placement.items() if v}
        print(f"   t <= {budget:8.2f} ns  clk {clock:4.2f}  "
              f"E_task {e.e_task_pj:10.1f} pJ  {share}")


def main() -> None:
    for model in sp.TINYML_MODELS.values():
        sweep(model, rho=4.0)
        print()
    print("== rho sensitivity (EfficientNet-B0): the LP-MRAM-only regime "
          "appears once weight fetches amortize over >=2 MACs ==")
    for rho in (1.0, 2.0, 4.0, 16.0):
        sweep(sp.EFFICIENTNET_B0, rho)
        print()
    dvfs_frontier()


if __name__ == "__main__":
    main()
