"""Quickstart: the paper's core in 60 seconds.

Builds the HH-PIM system from Tables I/III/V, runs the placement optimizer
(Algorithms 1+2) for EfficientNet-B0, prints the Fig.6-style placement
migration, and simulates one dynamic-workload scenario against the three
comparison PIMs (Fig. 5).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro import api
from repro.core import spaces as sp
from repro.core.system import (default_t_slice_ns, run_baseline, run_hh_pim)

RHO = 4.0


def main() -> None:
    model = sp.EFFICIENTNET_B0
    sub = api.substrate("edge-hhpim")
    arch = sub.arch
    em = sub.energy_model(model, rho=RHO)
    T = default_t_slice_ns(model, RHO)

    print(f"== HH-PIM ({arch.name}) / {model.name} ==")
    print(f"   {model.n_params:,} weights, {model.pim_ops:,} PIM MACs/task, "
          f"time slice T = {T / 1e6:.2f} ms\n")

    peak = em.peak_placement(sram_only=True)
    t_peak = em.task_cost(peak).t_task_ns / 1e6
    print(f"peak placement (green dot): {peak}  -> {t_peak:.3f} ms/task")
    mram = em.peak_placement(sram_only=False)
    t_mram = em.task_cost(mram).t_task_ns / 1e6
    print(f"MRAM-only peak (purple dot): {t_mram:.3f} ms/task  "
          "(paper: SRAM+MRAM wins)\n")

    print("placement LUT (allocation_state) - Fig. 6 migration:")
    lut = api.lut(sub, model, t_slice_ns=T, n_points=24, rho=RHO)
    seen = None
    for e in lut.entries:
        if not e.feasible:
            continue
        used = {k: v for k, v in e.placement.items() if v}
        key = tuple(sorted(used))
        if key != seen:
            seen = key
            print(f"  t_constraint >= {e.t_constraint_ns/1e6:6.2f} ms : "
                  f"{used}  E_task = {e.e_task_pj*1e-6:8.1f} uJ")

    print("\nscenario case3 (periodic spikes), 50 slices:")
    hh = run_hh_pim(model, "case3_periodic_spike", rho=RHO, lut_points=32)
    print(f"  HH-PIM        : {hh.energy_uj:10.1f} uJ, "
          f"{hh.deadline_miss} deadline misses")
    for kind in ("baseline", "hetero", "hybrid"):
        res = run_baseline(kind, model, "case3_periodic_spike", rho=RHO)
        save = 100 * (1 - hh.energy_uj / res.energy_uj)
        print(f"  {kind:14s}: {res.energy_uj:10.1f} uJ  "
              f"(HH-PIM saves {save:5.1f} %)")


if __name__ == "__main__":
    main()
