"""End-to-end driver: serve a small LM with batched requests through the
HH-PIM heterogeneous runtime (the paper's kind of system = inference).

A 12-layer/768-d transformer (the paper-equivalent ~125M edge config) is
served over simulated HP/LP TPU pools. Requests arrive per the paper's
Fig. 4 workload scenarios; every time slice the scheduler re-solves weight
placement across {hp,lp} x {bf16,int8} tiers (the SAME Algorithms 1+2, TPU
parameterization), the engine actually re-quantizes/re-splits the FFN
weights, and decodes one token per active request. Energy/latency per
slice are reported against a static-placement baseline.

Run:  PYTHONPATH=src python examples/serve_dynamic.py [--scenario case6_random]
"""
import argparse

import jax

from repro import api
from repro.configs import get_config
from repro.core import workloads
from repro.models import lm
from repro.models.common import reduced


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="case3_periodic_spike",
                    choices=list(workloads.SCENARIOS))
    ap.add_argument("--slices", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_config("hhpim_edge"), n_layers=4, d_model=128,
                  d_ff=256, vocab_size=512)
    print(f"model: {cfg.n_layers}L d={cfg.d_model} ff={cfg.d_ff} "
          f"(reduced {get_config('hhpim_edge').name} for CPU demo)")
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = api.engine("tpu-pool", cfg, params, max_batch=8,
                     n_hp_chips=4, n_lp_chips=4)
    print(f"time slice (10 tasks at peak): {eng.t_slice_ms:.3f} ms")

    loads = workloads.SCENARIOS[args.scenario][: args.slices]
    print(f"scenario {args.scenario}: loads {loads}\n")
    header = "placement (hp_bf16/hp_int8/lp_bf16/lp_int8)"
    print(f"{'slice':>5} {'load':>4} {header:>46} {'E_slice uJ':>11} "
          f"{'retier':>6} {'deadline':>8}")
    for i, n in enumerate(loads):
        r = eng.run_slice(min(n, eng.max_batch))
        pl = r.report.placement
        frac = "/".join(
            f"{100*pl.get(k,0)/max(sum(pl.values()),1):.0f}%"
            for k in ("hp_sram", "hp_mram", "lp_sram", "lp_mram"))
        print(f"{i:5d} {n:4d} {frac:>46} "
              f"{r.report.energy_pj*1e-6:11.2f} "
              f"{'yes' if r.retiered else '-':>6} "
              f"{'ok' if r.report.deadline_met else 'MISS':>8}")
        if len(r.tokens):
            pass  # decoded tokens available in r.tokens

    print(f"\ntotal energy: {eng.energy_uj():.1f} uJ, "
          f"deadline misses: {eng.deadline_misses()}")

    # static-placement comparison (peak placement all slices)
    from repro.core.scheduler import FixedPlacementScheduler
    fx = FixedPlacementScheduler(
        eng.arch, eng.model_spec, t_slice_ns=eng.t_slice_ms * 1e6,
        placement=eng.sched.em.peak_placement(True), rho=eng.sched.rho)
    e_fixed = sum(fx.step(min(n, eng.max_batch)).energy_pj
                  for n in loads) * 1e-6
    save = 100 * (1 - eng.energy_uj() / e_fixed)
    print(f"static peak placement would use {e_fixed:.1f} uJ -> dynamic "
          f"placement saves {save:.1f} % (the paper's core result, on TPU "
          f"pool constants)")


if __name__ == "__main__":
    main()
