"""Train a small LM for a few hundred steps on CPU, demonstrating the
training substrate end to end: synthetic data pipeline, AdamW + cosine
schedule, loss curve, async atomic checkpointing, preemption-safe resume,
and optional int8 gradient compression.

Run:  PYTHONPATH=src python examples/train_tiny.py [--steps 200] [--compress]
"""
import argparse
import tempfile

import jax.numpy as jnp

from repro.data.synthetic import DataConfig
from repro.models.common import ModelConfig
from repro.optim.adamw import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--compress", action="store_true",
                    help="int8 + error-feedback gradient compression")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = ModelConfig(name="tiny_lm", family="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                      vocab_size=512, head_dim=32, dtype=jnp.float32,
                      scan_layers=False, remat=False)
    n_params = None
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="hhpim_ckpt_")

    trainer = Trainer(
        cfg,
        OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                        weight_decay=0.01),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=16,
                   structure=0.85),
        TrainerConfig(steps=args.steps, ckpt_every=50, ckpt_dir=ckpt_dir,
                      grad_compression=args.compress))
    if trainer.maybe_resume():
        print(f"resumed from checkpoint at step {trainer.step}")
    import jax
    n_params = sum(x.size for x in jax.tree.leaves(trainer.params))
    print(f"model: {n_params/1e6:.1f} M params; steps: {args.steps}; "
          f"compression: {args.compress}; ckpt: {ckpt_dir}")

    out = trainer.run()
    for m in trainer.metrics_log[:: max(len(trainer.metrics_log) // 10, 1)]:
        print(f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"{m['sec']*1e3:6.1f} ms")
    print(f"\nloss {out['first_loss']:.4f} -> {out['final_loss']:.4f} over "
          f"{out['steps']} steps "
          f"(median step {out['median_step_s']*1e3:.1f} ms, "
          f"{out['straggler_steps']} straggler steps)")
    assert out["final_loss"] < out["first_loss"], "loss must decrease"


if __name__ == "__main__":
    main()
