"""Render EXPERIMENTS.md SS.Dry-run and SS.Roofline tables from the JSON
artifacts. Usage: PYTHONPATH=src python experiments/render_tables.py"""
import json
from pathlib import Path

HERE = Path(__file__).parent


def dryrun_table() -> str:
    rows = []
    for f in sorted(HERE.glob("dryrun/*.json")):
        d = json.loads(f.read_text())
        arch, shape, mesh = d["cell"].split("__")
        if d["status"] == "skipped":
            rows.append((arch, shape, mesh, "skipped", "-", "-", "-", "-"))
            continue
        mem = d.get("memory", {})
        per_dev = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0)) / 2 ** 30
        coll = d.get("collectives", {}).get("total", 0) / 2 ** 30
        rows.append((arch, shape, mesh, d["status"],
                     f"{d.get('compile_s', 0):.1f}s",
                     f"{per_dev:.2f}", f"{coll:.2f}",
                     d.get("optimizer", "-") if d["kind"] == "train"
                     else ("tp" if d.get("tp_only_params") else "fsdp")))
    out = ["| arch | shape | mesh | status | compile | GiB/dev | coll GiB/dev | sharding/opt |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def roofline_table(path: str) -> str:
    rows = json.loads((HERE / path).read_text())
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant"
           " | frac | useful |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                       f"skipped | - | - |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['roofline_fraction']:.3f} | "
            f"{r['useful_ratio']:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    print("## Dry-run matrix\n")
    print(dryrun_table())
    print("\n## Roofline (single pod)\n")
    print(roofline_table("roofline_single.json"))
