"""``repro.api`` - the one facade for constructing the HH-PIM stack.

Every entry point (launch CLIs, benchmarks, examples, fleets) builds
schedulers, serve engines and fleets through this module instead of
hand-wiring ``(arch, model, em, lut, rho, t_slice)`` tuples. Substrates
and solvers are string-keyed registries (DESIGN.md SS.5):

    from repro import api

    sched = api.scheduler("edge-hhpim", "efficientnet_b0", rho=4.0)
    sched = api.scheduler("edge-hybrid", model)        # fixed Table I policy
    sched = api.scheduler("tpu-pool", cfg, solver="dp")
    sched = api.scheduler("gpu-pool", cfg, lp_clock=0.6)  # DVFS knob
    lut   = api.lut("edge-hhpim", model, t_slice_ns=T)
    eng   = api.engine("tpu-pool", cfg, params, max_batch=4)
    fl    = api.fleet("tpu-pool-mixed", n_engines=4, forecaster="holt")
    hf    = api.hierarchical_fleet(n_cells=32, engines_per_cell=16,
                                   autoscale=True)   # DESIGN.md SS.9

    pc = api.compiler()                  # batched LUT build service
    fl = api.fleet("gpu-pool-mixed", n_engines=8, compiler=pc)
    pc.stats()                           # {"entries": 2, "builds": 2, ...}

Adding a backend = one ``register_substrate`` entry; adding a placement
strategy = one ``register_solver`` entry. The
:class:`~repro.core.compiler.PlacementCompiler` (DESIGN.md SS.6) is the
batched LUT build service: fleets compile all distinct (substrate
variant, model shape, slowdown) keys in one pass and schedulers route
straggler-rescaling rebuilds through its shared cache. This module IS
the construction API: the PR 2 legacy constructors
(``TimeSliceScheduler(arch, model, ...)``, ``make_baseline_scheduler``,
``build_fleet``) completed their one-release deprecation and are gone.
"""
from __future__ import annotations

from typing import Optional, Union

from repro.core.compiler import PlacementCompiler
from repro.core.scheduler import FixedPlacementScheduler, TimeSliceScheduler
from repro.core.solvers import (SOLVERS, FixedPolicySolver,  # noqa: F401
                                PlacementSolver, make_solver,
                                register_solver)
from repro.core.substrate import (SUBSTRATES, Substrate,  # noqa: F401
                                  available_substrates, list_substrates,
                                  make_substrate, register_substrate)
from repro.core.techmodel import (TECH_MODELS, DVFSController,  # noqa: F401
                                  TechModel, available_tech_models,
                                  get_tech_model, register_tech_model)

__all__ = [
    "substrate", "solver", "lut", "scheduler", "engine", "fleet",
    "hierarchical_fleet", "dag_fleet", "compiler", "obs",
    "PlacementCompiler",
    "Substrate", "PlacementSolver", "SUBSTRATES", "SOLVERS",
    "register_substrate", "register_solver", "available_substrates",
    "list_substrates", "TechModel", "DVFSController", "TECH_MODELS",
    "tech_model", "register_tech_model", "available_tech_models",
]


def tech_model(name: str) -> TechModel:
    """Resolve a registered :class:`~repro.core.techmodel.TechModel`
    (the per-tech-node vdd/freq/power curve + DVFS bounds behind a
    substrate's clock axis, DESIGN.md SS.10)."""
    return get_tech_model(name)


def compiler() -> PlacementCompiler:
    """A fresh :class:`~repro.core.compiler.PlacementCompiler` - the
    batched LUT build service. Pass the same instance to several
    ``scheduler``/``engine``/``fleet`` calls to share one build cache."""
    return PlacementCompiler()


def obs():
    """The process-wide observability facade (:mod:`repro.obs`,
    DESIGN.md SS.8): ``obs().enable()`` turns on tracing, ``obs().
    tracer()``/``metrics()``/``flight_recorder()`` read back the
    recorded state, ``obs().export(trace_path, metrics_path)`` writes
    Perfetto-loadable ``trace.json`` and a ``metrics.json`` snapshot."""
    from repro import obs as _obs
    return _obs


def substrate(name: Union[str, Substrate], **over) -> Substrate:
    """Resolve a substrate by registry name (instances pass through;
    keyword overrides go to the factory / ``dataclasses.replace``)."""
    return make_substrate(name, **over)


def solver(name: Union[str, PlacementSolver]) -> PlacementSolver:
    """Resolve a placement solver by registry name."""
    return make_solver(name)


def lut(sub: Union[str, Substrate], workload=None, *, solver=None,
        t_slice_ns: Optional[float] = None, n_points: Optional[int] = None,
        rho: Optional[float] = None,
        compiler: Optional[PlacementCompiler] = None, **over):
    """Build a :class:`~repro.core.placement.PlacementLUT` for a substrate
    workload through its (or the named) solver; an explicit ``compiler``
    routes the build through its shared cache.

    ``solver="dp"`` runs the fused on-device lut_pipeline op (one launch
    for the whole t-grid; ``REPRO_LUT_BACKEND`` overrides the backend,
    and a ``LUTMethodSolver(..., lut_backend=...)`` instance pins it
    per-solver). The returned LUT's ``backend`` attribute records which
    engine built it; all backends are byte-identical."""
    return substrate(sub, **over).build_lut(
        workload, solver=solver, t_slice_ns=t_slice_ns, n_points=n_points,
        rho=rho, compiler=compiler)


def scheduler(sub: Union[str, Substrate], workload=None, *, solver=None,
              t_slice_ns: Optional[float] = None,
              rho: Optional[float] = None, lut=None,
              lut_points: Optional[int] = None, initial_placement=None,
              compiler: Optional[PlacementCompiler] = None,
              dvfs=None, **over):
    """Construct the per-slice runtime for a substrate workload.

    Dynamic solvers (``closed-form``/``dp``) yield a
    :class:`~repro.core.scheduler.TimeSliceScheduler`; the degenerate
    ``fixed-*`` solvers yield a
    :class:`~repro.core.scheduler.FixedPlacementScheduler` (the Table I
    comparison-group semantics: no migration, no movement accounting).
    A shared ``compiler`` lets several schedulers reuse one LUT cache.

    ``dvfs`` attaches the online per-slice DVFS controller (DESIGN.md
    SS.10) on substrates with a registered TechModel: ``True`` for the
    default clock grid, an int for the grid size, a sequence for
    explicit clock points, or a prebuilt
    :class:`~repro.core.techmodel.DVFSController`.
    """
    s = substrate(sub, **over)
    model = s.model_spec(workload)
    rho = s.rho if rho is None else rho
    if t_slice_ns is None:
        t_slice_ns = s.default_t_slice_ns(model, rho=rho)
    sol = make_solver(solver or s.solver)
    if sol.fixed:
        if dvfs is not None:
            raise ValueError(
                "the DVFS controller needs a dynamic solver; fixed-* "
                "policies run at the substrate's static operating point")
        em = s.energy_model(model, rho=rho)
        return FixedPlacementScheduler(
            s.arch, model, t_slice_ns=t_slice_ns,
            placement=sol.initial_placement(em), rho=rho)
    return TimeSliceScheduler.from_substrate(
        s, model, t_slice_ns=t_slice_ns, rho=rho, solver=sol, lut=lut,
        initial_placement=initial_placement, lut_points=lut_points,
        compiler=compiler, dvfs=dvfs)


def engine(sub: Union[str, Substrate] = "tpu-pool", cfg=None, params=None,
           *, t_slice_ms: Optional[float] = None, max_batch: int = 16,
           seed: int = 0, lut_points: Optional[int] = None,
           compiler: Optional[PlacementCompiler] = None, **over):
    """Construct a functional serve engine (weights actually re-tiered per
    placement) on a decode-capable pool substrate (tpu/gpu pools and the
    cxl tiers; the substrate's ``tier_plan`` sets the column split)."""
    from repro.serve.hetero import HeteroServeEngine
    s = substrate(sub, **over)
    if not s.supports_decode:
        raise ValueError(
            f"substrate {s.name!r} has no functional serve engine "
            f"(accounting-only); use a substrate with supports_decode "
            f"(tpu-pool / gpu-pool / cxl-tier families)")
    return HeteroServeEngine(cfg, params, substrate=s,
                             t_slice_ms=t_slice_ms, max_batch=max_batch,
                             seed=seed, lut_points=lut_points,
                             compiler=compiler)


def fleet(sub: Union[str, Substrate] = "tpu-pool", cfg=None, *,
          n_engines: int = 2, forecaster: str = "ewma",
          policy: str = "slo", tokens_per_task: Optional[int] = None,
          rho: Optional[float] = None, t_slice_ms: Optional[float] = None,
          lut_points: Optional[int] = None,
          admission_limit: Optional[int] = None, slo_slices: float = 2.0,
          forecast_margin: float = 1.0, params=None, decode: bool = False,
          max_batch: int = 16, forecaster_kw: Optional[dict] = None,
          workload=None, compiler: Optional[PlacementCompiler] = None,
          dvfs=None, **over):
    """Construct a fleet of ``n_engines`` serve engines on one substrate.

    Engine shapes come from ``substrate.engine_variant(i)`` (the
    ``tpu-pool-mixed`` substrate gives odd engines half the chips);
    engines with the same shape share one placement LUT, batch-built by
    a :class:`~repro.core.compiler.PlacementCompiler` (pass one in to
    share its cache across fleets; the same compiler also serves every
    worker's straggler-rescaling rebuilds). ``decode=True`` (TPU
    substrates, requires ``params``) attaches a real
    ``HeteroServeEngine`` per worker so every placement change re-tiers
    actual weights and decodes tokens through them.

    ``dvfs`` turns the fleet's clock into a solved variable (DESIGN.md
    SS.10): ``True``/int/sequence builds one
    :class:`~repro.core.techmodel.DVFSController` per engine *shape*
    (grid LUTs batch-built through the shared compiler at bring-up,
    deduped exactly like the base LUTs), shared by every worker of that
    shape; each worker's scheduler then solves the energy-minimal
    (placement, clock) pair per slice.
    """
    from repro.fleet.forecast import make_forecaster
    from repro.fleet.router import EngineWorker, Fleet

    s = substrate(sub, **over)
    if tokens_per_task is None:
        # registry names get the fleet default; a pre-configured Substrate
        # instance keeps whatever it was built with
        tokens_per_task = (s.tokens_per_task
                           if not isinstance(sub, str)
                           and hasattr(s, "tokens_per_task") else 2)
    if hasattr(s, "tokens_per_task") and s.tokens_per_task != tokens_per_task:
        s = s.replace(tokens_per_task=tokens_per_task)
    rho = s.rho if rho is None else rho
    if rho != s.rho:
        s = s.replace(rho=rho)
    model = s.model_spec(workload if workload is not None else cfg)

    variants = [s.engine_variant(i) for i in range(n_engines)]
    shapes = {}
    for v in variants:
        shapes.setdefault(v.variant_key(), v)

    if t_slice_ms is None:
        # fleet-wide slice = the fastest engine shape's default sizing
        t_slice_ms = min(v.default_t_slice_ns(model, rho=rho)
                         for v in shapes.values()) / 1e6
    t_slice_ns = t_slice_ms * 1e6

    # one LUT per distinct engine shape, batch-built by the placement
    # compiler (one pass over the deduplicated shapes) and shared by all
    # instances; the same compiler serves straggler-rescaling rebuilds
    pc = compiler if compiler is not None else PlacementCompiler()
    luts = pc.compile(shapes.values(), model, t_slice_ns=t_slice_ns,
                      n_points=lut_points, rho=rho)

    # one DVFS controller per engine SHAPE (controllers are stateless
    # across slices, so same-shape workers share one grid of LUTs)
    controllers = {}
    if dvfs is not None and dvfs is not False:
        from repro.core.techmodel import DVFSController
        kw = {}
        if isinstance(dvfs, DVFSController):
            raise ValueError(
                "pass dvfs=True/int/sequence to fleet(); controllers are "
                "per engine shape and built internally")
        if isinstance(dvfs, int) and not isinstance(dvfs, bool):
            kw["n_clocks"] = dvfs
        elif not isinstance(dvfs, bool):
            kw["clocks"] = tuple(dvfs)
        for vk, v in shapes.items():
            controllers[vk] = DVFSController(
                v, model, t_slice_ns=t_slice_ns, rho=rho,
                lut_points=lut_points, compiler=pc, **kw)
            controllers[vk].prepare()

    workers = []
    for i, v in enumerate(variants):
        hetero = None
        if decode:
            if params is None:
                raise ValueError("decode=True requires model params")
            eng = engine(v, cfg, params, t_slice_ms=t_slice_ns / 1e6,
                         max_batch=max_batch, lut_points=lut_points,
                         compiler=pc)
            sched = eng.sched
            sched._lut_cache[sched._slowdown_key()] = luts[v.variant_key()]
            hetero = eng
        else:
            sched = TimeSliceScheduler.from_substrate(
                v, model, t_slice_ns=t_slice_ns, rho=rho,
                lut=luts[v.variant_key()], lut_points=lut_points,
                compiler=pc)
        if controllers:
            sched.dvfs = controllers[v.variant_key()]
        workers.append(EngineWorker(
            i, sched, make_forecaster(forecaster, **(forecaster_kw or {})),
            hetero=hetero, substrate=v, forecast_margin=forecast_margin))
    return Fleet(workers, policy=policy, admission_limit=admission_limit,
                 slo_slices=slo_slices, tokens_per_request=tokens_per_task)


def hierarchical_fleet(sub: Union[str, Substrate] = "tpu-pool", cfg=None,
                       *, n_cells: int = 4, engines_per_cell: int = 4,
                       forecaster: str = "ewma",
                       budgets: Optional[dict] = None,
                       class_mix: Optional[dict] = None,
                       cell_policy: str = "least_loaded",
                       energy_weight: float = 0.05,
                       admit_headroom: float = 1.0,
                       autoscale: bool = False,
                       min_engines: Optional[int] = None,
                       max_engines: Optional[int] = None,
                       autoscale_kw: Optional[dict] = None,
                       tokens_per_task: Optional[int] = None,
                       rho: Optional[float] = None,
                       t_slice_ms: Optional[float] = None,
                       lut_points: Optional[int] = None,
                       slo_slices: float = 2.0,
                       forecast_margin: float = 1.0,
                       forecaster_kw: Optional[dict] = None,
                       workload=None,
                       compiler: Optional[PlacementCompiler] = None,
                       seed: int = 0, **over):
    """Construct a two-level (cell -> engine) fleet (DESIGN.md SS.9).

    ``n_cells`` cells of ``engines_per_cell`` engines each; one
    substrate variant per cell (``sub`` may also be a list of substrate
    names/instances, cycled across cells - with a mixed substrate,
    odd-indexed CELLS get the half shape). All engines of a cell share
    one placement LUT; the fleet-wide
    :class:`~repro.core.compiler.PlacementCompiler` batch-builds every
    distinct shape at bring-up, so ``n_cells x engines_per_cell``
    engines cost at most ``n_cells`` builds (typically 1-2) and a
    warm-started compiler (``pc.load(...)``) costs zero.

    ``budgets`` maps SLO class -> latency budget in slices (default
    ``{"default": slo_slices}``); ``class_mix`` maps class ->
    probability for seeded class assignment. ``autoscale=True`` attaches
    a :class:`~repro.fleet.hierarchy.CellAutoscaler` with per-cell
    bounds [``min_engines`` (default 1), ``max_engines`` (default
    ``engines_per_cell``)]; extra :class:`~repro.fleet.hierarchy.
    AutoscaleConfig` knobs go in ``autoscale_kw``. Scale-ups build new
    workers through the shared compiler, so they pay 0 LUT builds.

    The hierarchical path is analytic-only (scheduler + energy model);
    use :func:`fleet` with ``decode=True`` for functional token decode.
    """
    import itertools as _it

    from repro.fleet.forecast import make_forecaster
    from repro.fleet.hierarchy import (AutoscaleConfig, Cell,
                                       CellAutoscaler, HierarchicalFleet)
    from repro.fleet.router import EngineWorker

    names = list(sub) if isinstance(sub, (list, tuple)) else [sub]
    subs = []
    for nm in names:
        s = substrate(nm, **over)
        if tokens_per_task is None:
            tokens_per_task = (s.tokens_per_task
                               if not isinstance(nm, str)
                               and hasattr(s, "tokens_per_task") else 2)
        if (hasattr(s, "tokens_per_task")
                and s.tokens_per_task != tokens_per_task):
            s = s.replace(tokens_per_task=tokens_per_task)
        if rho is not None and rho != s.rho:
            s = s.replace(rho=rho)
        subs.append(s)

    # one substrate variant per CELL (cells are the unit of shape)
    cell_subs = [subs[i % len(subs)].engine_variant(i)
                 for i in range(n_cells)]
    shapes = {}
    for v in cell_subs:
        shapes.setdefault(v.variant_key(), v)
    models = {vk: v.model_spec(workload if workload is not None else cfg)
              for vk, v in shapes.items()}
    if t_slice_ms is None:
        t_slice_ms = min(
            v.default_t_slice_ns(models[vk])
            for vk, v in shapes.items()) / 1e6
    t_slice_ns = t_slice_ms * 1e6

    pc = compiler if compiler is not None else PlacementCompiler()
    luts = pc.compile(shapes.values(),
                      workload if workload is not None else cfg,
                      t_slice_ns=t_slice_ns, n_points=lut_points)

    wid = _it.count()

    def make_worker(v, lut=None):
        # lut=None routes the first LUT access through the shared
        # compiler (a warm cache hit for autoscaler scale-ups)
        sched = TimeSliceScheduler.from_substrate(
            v, models[v.variant_key()], t_slice_ns=t_slice_ns, lut=lut,
            lut_points=lut_points, compiler=pc)
        return EngineWorker(
            next(wid), sched,
            make_forecaster(forecaster, **(forecaster_kw or {})),
            substrate=v, forecast_margin=forecast_margin)

    cells = [Cell(cid, [make_worker(v, lut=luts[v.variant_key()])
                        for _ in range(engines_per_cell)],
                  substrate=v, tokens_per_task=tokens_per_task)
             for cid, v in enumerate(cell_subs)]

    scaler = None
    if autoscale:
        acfg = AutoscaleConfig(
            min_engines=1 if min_engines is None else min_engines,
            max_engines=(engines_per_cell if max_engines is None
                         else max_engines),
            **(autoscale_kw or {}))
        scaler = CellAutoscaler(
            acfg, lambda cell: make_worker(cell.substrate), compiler=pc)

    return HierarchicalFleet(
        cells, budgets=budgets, class_mix=class_mix,
        slo_slices=slo_slices, tokens_per_request=tokens_per_task,
        autoscaler=scaler, cell_policy=cell_policy,
        energy_weight=energy_weight, admit_headroom=admit_headroom,
        seed=seed)


def dag_fleet(sub: Union[str, Substrate] = "tpu-pool", cfg=None, *,
              tenants=None, budgets: Optional[dict] = None,
              stage_affinity: bool = True,
              handoff_tax_slices: float = 0.25,
              handoff_energy_pj: float = 2e5,
              affinity_bonus: float = 0.1, **kw):
    """Construct a multi-tenant DAG-serving fleet (DESIGN.md SS.11).

    Same cell bring-up as :func:`hierarchical_fleet` (every keyword it
    takes passes through - ``n_cells``, ``engines_per_cell``,
    ``compiler``, ``autoscale``, ...), returning a
    :class:`~repro.fleet.dag.DagFleet` whose :meth:`~repro.fleet.dag.
    DagFleet.run_dag` co-schedules DAG *stages* across the cells.
    ``tenants`` is a :class:`~repro.fleet.dag.TenantRegistry` (or a
    sequence of :class:`~repro.fleet.dag.Tenant`); the default registry
    is :func:`~repro.fleet.dag.default_tenants` with matching
    ``budgets`` - every tenant's SLO class must be registered in
    ``budgets`` or construction raises a shaped error. Stage placement
    reads the per-variant LUTs compiled at bring-up, so a DAG fleet
    pays **zero** placement builds beyond the plain fleet's set."""
    from repro.fleet.dag import (DEFAULT_DAG_BUDGETS, DagFleet, Tenant,
                                 TenantRegistry, default_tenants)

    if tenants is None:
        tenants = default_tenants()
    elif not isinstance(tenants, TenantRegistry):
        tenants = TenantRegistry(tuple(
            t if isinstance(t, Tenant) else Tenant(**t) for t in tenants))
    if budgets is None:
        budgets = dict(DEFAULT_DAG_BUDGETS)
    hf = hierarchical_fleet(sub, cfg, budgets=budgets, **kw)
    return DagFleet(
        hf.cells, tenants=tenants, stage_affinity=stage_affinity,
        handoff_tax_slices=handoff_tax_slices,
        handoff_energy_pj=handoff_energy_pj,
        affinity_bonus=affinity_bonus, budgets=hf.router.budgets,
        slo_slices=hf.slo_slices,
        tokens_per_request=hf.tokens_per_request,
        autoscaler=hf.autoscaler, cell_policy=hf.router.cell_policy,
        energy_weight=hf.router.energy_weight,
        admit_headroom=hf.router.admit_headroom, seed=hf.seed)
