"""Fault-tolerant checkpointing: atomic, async, mesh-elastic.

  * atomic   - write to ``<dir>.tmp`` then rename; a crash mid-write never
               corrupts the latest checkpoint.
  * async    - ``AsyncCheckpointer`` snapshots device arrays to host and
               writes on a worker thread; the train loop never blocks on IO.
  * elastic  - restore() rebuilds arrays under ANY target sharding/mesh:
               checkpoints are stored as full (host) arrays per leaf, so a
               job can restart on a different topology (tested 8->4->8
               devices), the core of elastic scaling.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

PyTree = Any

_SEP = "__"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(tree: PyTree, directory: str | os.PathLike, step: int) -> Path:
    """Synchronous atomic save. Returns the final checkpoint path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    treedef = jax.tree_util.tree_structure(tree)
    (tmp / "meta.json").write_text(json.dumps({
        "step": step, "treedef": str(treedef),
        "keys": sorted(flat.keys())}))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic on POSIX
    return final


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(template: PyTree, directory: str | os.PathLike,
            step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``template``; if ``shardings`` given,
    place each leaf with that sharding (elastic re-shard on a new mesh)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = directory / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_p))
    out = []
    for (tpath, leaf), shard in zip(leaves_p, shard_leaves):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in tpath)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


class AsyncCheckpointer:
    """Snapshot-to-host immediately, write on a background thread."""

    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._errors: list = []

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            tree_host, step = item
            try:
                save(tree_host, self.directory, step)
                self._gc()
            except Exception as e:      # pragma: no cover
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.directory.glob("step_*")
                       if not p.name.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)

    def save_async(self, tree: PyTree, step: int) -> None:
        host = jax.tree.map(np.asarray, tree)    # device->host snapshot now
        self._q.put((host, step))

    def wait(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        self._q.put(None)
        self._worker.join(timeout=30)
