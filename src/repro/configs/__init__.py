"""Architecture configs (assigned pool + the paper's own TinyML models)."""
from repro.configs.registry import (ALIASES, ARCH_IDS, all_configs,
                                    canonical, get_config, get_smoke_config)

__all__ = ["ALIASES", "ARCH_IDS", "all_configs", "canonical", "get_config",
           "get_smoke_config"]
