"""arctic-480b [moe] - 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base; hf].
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.

Arctic's dense-MoE hybrid: every layer has a (small) dense residual MLP in
parallel with the 128-expert top-2 MoE (``moe_dense_ff``). This is the
paper-technique showcase arch: expert popularity is the dynamic "inference
load", and the HH-PIM placement LUT assigns cold experts to the LP/int8
tier (DESIGN.md SS.5).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="arctic_480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    n_experts=128,
    experts_per_token=2,
    moe_dense_ff=4864,
)
