"""chatglm3-6b [dense] - 2d RoPE, GQA kv=2 [arXiv:2406.12793; hf].
28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3_6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65_024,
    rope_kind="2d",
)
