"""The paper's own deployment point: a small edge LM served through the
HH-PIM tiered runtime (hp/lp x bf16/int8 weight segments, placement-driven).

The paper's benchmarks are TinyML CNNs (Table IV - see
``repro.core.spaces.TINYML_MODELS``); for the LM-serving framework this
config is the equivalent-scale transformer (~125M params) with HH-PIM
tier placement enabled (``tier_fractions`` = init split, re-optimized per
time slice by the serving runtime).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="hhpim_edge",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=32_000,
    mlp_act="gelu",
    tier_fractions=(0.4, 0.24, 0.0, 0.36),   # paper's 16:9 HP:LP peak split
)
