"""pixtral-12b [vlm] - pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified].
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.

The ViT frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (n_prefix_embeds per sample) prepended to the
text sequence; loss is computed on text positions only.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="pixtral_12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131_072,
    frontend="patch",
    n_prefix_embeds=256,      # one 1024px image at 16x16 patches / 4
)
