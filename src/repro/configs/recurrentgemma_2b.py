"""recurrentgemma-2b [hybrid] - RG-LRU + local attention, 1:2 attn:recurrent
[arXiv:2402.19427; hf]. 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000, local window 2048.

26 = 8 x (rglru, rglru, attn) + (rglru, rglru) tail - the Griffin pattern.
Sub-quadratic: runs the long_500k shape (recurrent state + 2k-window KV).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma_2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    attn_kind="local",
    local_window=2048,
    rope_kind="full",
    block_pattern=("rglru", "rglru", "attn"),
)
