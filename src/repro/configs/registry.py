"""Architecture registry: ``--arch <id>`` resolves here.

Each assigned architecture lives in its own module exporting ``CONFIG``;
``get_config(name)`` returns the full config, ``get_smoke_config(name)`` the
reduced same-family config used by CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig, reduced

ARCH_IDS: List[str] = [
    "recurrentgemma_2b",
    "qwen25_32b",
    "internlm2_1_8b",
    "chatglm3_6b",
    "phi3_medium_14b",
    "xlstm_1_3b",
    "pixtral_12b",
    "arctic_480b",
    "llama4_scout_17b_a16e",
    "seamless_m4t_medium",
]

# assignment ids (with dashes/dots) -> module names
ALIASES: Dict[str, str] = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen2.5-32b": "qwen25_32b",
    "internlm2-1.8b": "internlm2_1_8b",
    "chatglm3-6b": "chatglm3_6b",
    "phi3-medium-14b": "phi3_medium_14b",
    "xlstm-1.3b": "xlstm_1_3b",
    "pixtral-12b": "pixtral_12b",
    "arctic-480b": "arctic_480b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "seamless-m4t-medium": "seamless_m4t_medium",
}


def canonical(name: str) -> str:
    return ALIASES.get(name, name)


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    if hasattr(mod, "SMOKE_CONFIG"):
        return mod.SMOKE_CONFIG
    return reduced(mod.CONFIG)


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
