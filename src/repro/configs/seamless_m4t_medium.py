"""seamless-m4t-medium [audio] - encoder-decoder, multimodal
[arXiv:2308.11596; hf]. 12L d_model=1024 16H d_ff=4096 vocab=256206.

Encoder-decoder: 12 encoder + 12 decoder layers. The speech frontend is a
STUB per the assignment: ``input_specs()`` provides precomputed frame
embeddings (seq_len // 4 frames at ~50 Hz) as encoder input; the decoder is
an autoregressive text LM with cross-attention.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless_m4t_medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    mlp_act="gelu",
    n_encoder_layers=12,
    enc_len_divisor=4,
    frontend="frames",
)
