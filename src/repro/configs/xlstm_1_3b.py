"""xlstm-1.3b [ssm] - sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
48L d_model=2048 4H d_ff=0 vocab=50304.

Block ratio 7:1 mLSTM:sLSTM (the paper's xLSTM[7:1]); 48 = 6 x period-8
groups, cleanly scanned. d_ff=0: xLSTM blocks carry their own projections,
no separate FFN. Sub-quadratic: runs long_500k (O(1) recurrent state).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm_1_3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    head_dim=512,
    rope_kind="none",
    block_pattern=("mlstm",) * 7 + ("slstm",),
)
