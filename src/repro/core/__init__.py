"""Core HH-PIM library: the paper's primary contribution.

  spaces     - hardware constants (Tables I/III/IV/V) and arch builders
  energy     - timing/energy model of placements
  placement  - Algorithms 1+2 (verbatim DP) + closed-form solver + LUT
  scheduler  - time-slice runtime (+ straggler feedback)
  solvers    - pluggable placement-solver strategy registry
  substrate  - Substrate protocol + string-keyed backend registry
  workloads  - Fig. 4 scenarios
  baselines  - Baseline-/Heterogeneous-/Hybrid-PIM comparison policies
  system     - end-to-end scenario simulation (Fig. 5 / Table VI)

Construct the stack through the ``repro.api`` facade (DESIGN.md SS.5).
"""
from repro.core import (baselines, energy, placement, scheduler, solvers,
                        spaces, substrate, system, workloads)

__all__ = ["baselines", "energy", "placement", "scheduler", "solvers",
           "spaces", "substrate", "system", "workloads"]
