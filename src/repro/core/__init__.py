"""Core HH-PIM library: the paper's primary contribution.

  spaces     - hardware constants (Tables I/III/IV/V) and arch builders
  energy     - timing/energy model of placements
  placement  - Algorithms 1+2 (verbatim DP) + closed-form solver + LUT
  scheduler  - time-slice runtime (+ straggler feedback)
  workloads  - Fig. 4 scenarios
  baselines  - Baseline-/Heterogeneous-/Hybrid-PIM comparison policies
  system     - end-to-end scenario simulation (Fig. 5 / Table VI)
"""
from repro.core import (baselines, energy, placement, scheduler, spaces,
                        system, workloads)

__all__ = ["baselines", "energy", "placement", "scheduler", "spaces",
           "system", "workloads"]
