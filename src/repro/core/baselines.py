"""Comparison-group processors (Table I) and their fixed placement policies.

The policies are registered as degenerate solvers (``fixed-baseline`` /
``fixed-hetero`` / ``fixed-hybrid``) bound to the ``edge-*`` substrates;
construct their runtimes via ``repro.api.scheduler("edge-<kind>", ...)``.
"""
from __future__ import annotations

from typing import Tuple

from repro.core import spaces as sp
from repro.core.energy import EnergyModel, Placement


def baseline_policy(model: sp.ModelSpec) -> Tuple[sp.PIMArch, Placement]:
    """Baseline-PIM: 8 HP modules, all weights in (128 kB) SRAM."""
    arch = sp.baseline_pim()
    return arch, {"hp_sram": model.n_params}


def hetero_policy(model: sp.ModelSpec, rho: float = 1.0
                  ) -> Tuple[sp.PIMArch, Placement]:
    """Heterogeneous-PIM: 4 HP + 4 LP modules, SRAM-only; weights split to
    balance the two clusters' makespans (its best fixed operating point)."""
    arch = sp.hetero_pim()
    em = EnergyModel(arch, model, rho=rho)
    return arch, em.peak_placement(sram_only=True)


def hybrid_policy(model: sp.ModelSpec) -> Tuple[sp.PIMArch, Placement]:
    """Hybrid-PIM: 8 HP modules; weights in MRAM, SRAM as I/O buffer."""
    arch = sp.hybrid_pim()
    return arch, {"hp_mram": model.n_params}
