"""``repro.core.compiler`` - the batched placement compiler (DESIGN.md SS.6).

A :class:`PlacementCompiler` is the fleet-wide LUT build service: it
deduplicates ``(substrate variant, model shape, solver, slice, slowdown)``
keys and builds each missing :class:`~repro.core.placement.PlacementLUT`
exactly once through the batched solver drivers
(:func:`repro.core.placement.build_lut` with ``batched=True``), caching
the result. Fleet bring-up compiles every distinct engine shape in one
pass instead of once per engine, and straggler rescaling (the
scheduler's per-slowdown-signature LUT rebuild) hits the shared cache,
so two degraded engines of the same shape pay one rebuild between them.

Construct through ``repro.api.compiler()``; ``api.scheduler``,
``api.engine`` and ``api.fleet`` accept a ``compiler=`` to share one
cache across engines, fleets and slices.
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import Dict, Iterable, Optional, Tuple, Union

from repro import obs
from repro.core.energy import EnergyModel
from repro.core.placement import LUTEntry, PlacementLUT, build_lut_grid
from repro.core.solvers import (LUTMethodSolver, PlacementSolver,
                                make_solver)

CacheKey = Tuple

#: serialized LUT-cache format version (bump on incompatible changes;
#: load() skips files with a different version instead of raising)
CACHE_FORMAT_VERSION = 1


def _key_to_jsonable(key):
    """Cache keys are nested tuples of str/int/float; JSON stores them
    as nested lists."""
    if isinstance(key, tuple):
        return [_key_to_jsonable(k) for k in key]
    return key


def _key_from_jsonable(key):
    if isinstance(key, list):
        return tuple(_key_from_jsonable(k) for k in key)
    return key


def slowdown_signature(time_scale) -> tuple:
    """Canonical per-cluster slowdown key. The single source of truth
    for slowdown rounding: the scheduler's per-engine ``_lut_cache`` and
    this compiler's shared cache both key through it, so the two layers
    always address the same entry (DESIGN.md SS.6)."""
    return tuple(sorted((c, round(float(f), 3))
                        for c, f in dict(time_scale).items()))


class PlacementCompiler:
    """Batch LUT builder with one shared cache across engines and fleets."""

    def __init__(self) -> None:
        self._cache: Dict[CacheKey, PlacementLUT] = {}
        self.n_builds = 0          # cache misses -> actual solver runs
        self.n_hits = 0            # served from cache
        self.n_loaded = 0          # entries merged in by load() warm starts
        # per-build resolved lut_pipeline backend ("host" for the
        # closed-form / fixed / per-point paths): which engine actually
        # built each cache miss
        self.n_builds_by_backend: Dict[str, int] = {}

    def _record_build(self, lut: PlacementLUT) -> None:
        b = getattr(lut, "backend", None) or "host"
        self.n_builds += 1
        self.n_builds_by_backend[b] = self.n_builds_by_backend.get(b, 0) + 1
        obs.metrics().counter("compiler.lut.build")

    # -- keys ---------------------------------------------------------------
    @staticmethod
    def cache_key(*, variant_key: tuple, model, solver_name: str,
                  t_slice_ns: float, n_points: int, rho: float,
                  static_window: str, slowdown: tuple) -> CacheKey:
        return (tuple(variant_key), model.name, int(model.n_params),
                solver_name, float(t_slice_ns), int(n_points), float(rho),
                static_window, tuple(slowdown))

    # -- single build -------------------------------------------------------
    def lut(self, em: EnergyModel, *,
            solver: Union[str, PlacementSolver],
            t_slice_ns: float, n_points: int,
            static_window: str = "t_constraint",
            variant_key: Optional[tuple] = None) -> PlacementLUT:
        """Build-or-fetch one LUT. ``em.time_scale`` (straggler slowdown)
        and ``em.rho`` are part of the key, so a degraded engine gets its
        own entry while identical engines share one."""
        sol = make_solver(solver)
        key = self.cache_key(
            variant_key=variant_key or (em.arch.name,), model=em.model,
            solver_name=sol.name, t_slice_ns=t_slice_ns,
            n_points=n_points, rho=em.rho, static_window=static_window,
            slowdown=slowdown_signature(em.time_scale))
        hit = self._cache.get(key)
        # cache traffic is mirrored into the metrics registry
        # unconditionally (rare events): the fleet CLI's lut-cache line
        # and the flight recorder's lut_cache frame field read it there
        if hit is not None:
            self.n_hits += 1
            obs.metrics().counter("compiler.lut.hit")
            return hit
        with obs.span("compiler.lut_build", "compiler",
                      variant=str(key[0]), model=key[1],
                      solver=sol.name, n_points=n_points) as sp_:
            built = sol.build_lut(em, t_slice_ns=t_slice_ns,
                                  n_points=n_points,
                                  static_window=static_window)
            sp_.set("backend", getattr(built, "backend", None) or "host")
        self._record_build(built)
        self._cache[key] = built
        return built

    def lut_grid(self, ems, *, solver: Union[str, PlacementSolver],
                 t_slice_ns: float, n_points: int,
                 static_window: str = "t_constraint",
                 variant_keys=None) -> list:
        """Build-or-fetch LUTs for a batch of substrate variants.

        Cache hits are served per variant; with a batched dp solver
        every *miss* is stacked on the fused lut_pipeline op's variant
        axis and solved in ONE device launch
        (:func:`repro.core.placement.build_lut_grid`) - the DVFS clock
        grid path (DESIGN.md SS.10). Other solvers fall back to one
        :meth:`lut` call per miss. Results keep ``ems`` order.
        """
        sol = make_solver(solver)
        if variant_keys is None:
            variant_keys = [(em.arch.name,) for em in ems]
        ems = list(ems)
        keys = [self.cache_key(
            variant_key=vk, model=em.model, solver_name=sol.name,
            t_slice_ns=t_slice_ns, n_points=n_points, rho=em.rho,
            static_window=static_window,
            slowdown=slowdown_signature(em.time_scale))
            for em, vk in zip(ems, variant_keys)]
        luts = [self._cache.get(k) for k in keys]
        for lut in luts:
            if lut is not None:
                self.n_hits += 1
                obs.metrics().counter("compiler.lut.hit")
        missing = [i for i, lut in enumerate(luts) if lut is None]
        fusable = (isinstance(sol, LUTMethodSolver) and sol.method == "dp"
                   and sol.batched)
        if missing and fusable:
            miss = [ems[i] for i in missing]
            with obs.span("compiler.lut_build", "compiler",
                          variant="grid", model=miss[0].model.name,
                          solver=sol.name, n_points=n_points,
                          n_variants=len(miss)) as sp_:
                built = build_lut_grid(
                    miss, t_slice_ns=t_slice_ns, n_points=n_points,
                    static_window=static_window,
                    dp_backend=sol.dp_backend,
                    lut_backend=sol.lut_backend)
                sp_.set("backend",
                        getattr(built[0], "backend", None) or "host")
            for i, lut in zip(missing, built):
                self._record_build(lut)
                self._cache[keys[i]] = lut
                luts[i] = lut
        elif missing:
            for i in missing:
                luts[i] = self.lut(
                    ems[i], solver=sol, t_slice_ns=t_slice_ns,
                    n_points=n_points, static_window=static_window,
                    variant_key=variant_keys[i])
        return luts

    # -- fleet bring-up -----------------------------------------------------
    def compile(self, substrates: Iterable, workload=None, *,
                solver=None, t_slice_ns: Optional[float] = None,
                n_points: Optional[int] = None,
                rho: Optional[float] = None
                ) -> Dict[tuple, PlacementLUT]:
        """Batch-build LUTs for every distinct engine shape in one pass.

        ``substrates`` are (possibly repeated) engine variants; shapes
        are deduplicated on ``variant_key()`` before any build, so N
        engines of S distinct shapes cost S builds (or fewer, on cache
        hits from an earlier fleet). Returns ``{variant_key: lut}``.
        """
        out: Dict[tuple, PlacementLUT] = {}
        for sub in substrates:
            vk = sub.variant_key()
            if vk in out:
                continue
            model = sub.model_spec(workload)
            r = sub.rho if rho is None else rho
            em = sub.energy_model(model, rho=r)
            out[vk] = self.lut(
                em, solver=solver or sub.solver,
                t_slice_ns=(sub.default_t_slice_ns(model, rho=r)
                            if t_slice_ns is None else t_slice_ns),
                n_points=(sub.lut_points if n_points is None else n_points),
                static_window=sub.static_window, variant_key=vk)
        return out

    def compile_clock_grid(self, sub, workload=None, *,
                           clocks: Optional[Iterable[float]] = None,
                           n_clocks: int = 5, solver=None,
                           t_slice_ns: Optional[float] = None,
                           n_points: Optional[int] = None,
                           rho: Optional[float] = None
                           ) -> Dict[float, PlacementLUT]:
        """Batch-build one LUT per DVFS clock point of ``sub``'s
        TechModel grid (DESIGN.md SS.10). Returns ``{clock: lut}``.

        Each grid point is ``sub.with_clock(c)`` - a distinct
        ``variant_key()`` - so points dedupe fleet-wide exactly like
        engine shapes: N controllers on the same grid pay one build per
        point; with a batched dp solver all missing points are solved in
        ONE fused lut_pipeline launch (:meth:`lut_grid`). ``clocks=None``
        takes ``n_clocks`` evenly spaced points over the TechModel's
        DVFS bounds plus the substrate's default clock (the legacy
        static operating point stays on the grid)."""
        tm = sub.tech_model()
        if tm is None:
            raise ValueError(
                f"substrate {sub.name!r} has no registered TechModel; "
                f"no clock grid to compile")
        if clocks is None:
            default = getattr(sub, "lp_clock", None)
            include = () if default is None else (default,)
            clocks = tm.clock_grid(n_clocks, include=include)
        model = sub.model_spec(workload)
        r = sub.rho if rho is None else rho
        if t_slice_ns is None:
            t_slice_ns = sub.default_t_slice_ns(model, rho=r)
        clocks = list(clocks)
        variants = [sub.with_clock(c) for c in clocks]
        ems = [EnergyModel(v.arch, model, rho=r) for v in variants]
        luts = self.lut_grid(
            ems, solver=solver or sub.solver, t_slice_ns=t_slice_ns,
            n_points=(sub.lut_points if n_points is None else n_points),
            static_window=sub.static_window,
            variant_keys=[v.variant_key() for v in variants])
        return dict(zip(clocks, luts))

    # -- warm start ---------------------------------------------------------
    # Fleet restarts shouldn't pay bring-up compiles again: save() the
    # cache next to the checkpoints, load() it into the next process'
    # compiler, and every unchanged (variant, model, solver, slice,
    # slowdown) key becomes a cache hit. JSON keeps the bytes exact:
    # Python's float repr round-trips (including +-inf), so a reloaded
    # LUT compares equal (==) to the one that was built.

    def save(self, path) -> Path:
        """Serialize the LUT cache to ``path`` (atomic tmp+rename)."""
        with obs.span("compiler.save", "compiler", entries=len(self._cache)):
            return self._save(path)

    def _save(self, path) -> Path:
        path = Path(path)
        payload = {"version": CACHE_FORMAT_VERSION, "luts": []}
        for key, lut in self._cache.items():
            payload["luts"].append({
                "key": _key_to_jsonable(key),
                "arch": lut.arch_name, "model": lut.model_name,
                "entries": [dataclasses.asdict(e) for e in lut.entries]})
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(path.suffix + ".tmp")
        tmp.write_text(json.dumps(payload))
        os.replace(tmp, path)                # atomic on POSIX
        return path

    def load(self, path) -> int:
        """Merge a :meth:`save`d cache; existing keys win. Returns the
        number of LUTs added; a missing file is a cold start (0), a
        version mismatch is skipped rather than raised."""
        with obs.span("compiler.load", "compiler") as sp_:
            added = self._load(path)
            sp_.set("added", added)
            return added

    def _load(self, path) -> int:
        path = Path(path)
        if not path.exists():
            return 0
        payload = json.loads(path.read_text())
        if payload.get("version") != CACHE_FORMAT_VERSION:
            return 0
        added = 0
        for rec in payload["luts"]:
            key = _key_from_jsonable(rec["key"])
            if key in self._cache:
                continue
            entries = [LUTEntry(**e) for e in rec["entries"]]
            self._cache[key] = PlacementLUT(rec["arch"], rec["model"],
                                            entries)
            added += 1
        self.n_loaded += added
        # mirrored like build/hit traffic: warm-started entries are what
        # let autoscaler scale-ups report 0 builds (DESIGN.md SS.9)
        if added:
            obs.metrics().counter("compiler.lut.loaded", added)
        return added

    # -- introspection ------------------------------------------------------
    def __len__(self) -> int:
        return len(self._cache)

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._cache), "builds": self.n_builds,
                "hits": self.n_hits, "loaded": self.n_loaded,
                "builds_by_backend": dict(self.n_builds_by_backend)}
