"""Timing and energy model for PIM placements.

Implements the modeling contract of DESIGN.md SS.2:

  * one PIM op = one INT8 MAC on one stored weight; per-op latency is
    ``io_read + weight_read/rho + pe`` of the weight's home space,
  * ops parallelize across a cluster's modules, MRAM-resident and
    SRAM-resident ops within a module are serial (paper SS.III.B), HP and LP
    clusters run in parallel (task time = max over clusters),
  * static power: volatile banks holding weights stay on for the whole time
    slice; non-volatile banks (and empty volatile I/O banks) are power-gated
    whenever their cluster is idle; PE leaks while its cluster is busy,
  * re-placement pays the destination write (+ source read) energy and time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional

from repro.core import spaces as sp

Placement = Dict[str, int]   # space name -> number of weights stored there


def total_weights(placement: Mapping[str, int]) -> int:
    return int(sum(placement.values()))


def validate_placement(arch: sp.PIMArch, model: sp.ModelSpec,
                       placement: Mapping[str, int]) -> None:
    names = {s.name for s in arch.spaces}
    for k, v in placement.items():
        if k not in names:
            raise ValueError(f"unknown space {k!r} for arch {arch.name}")
        if v < 0:
            raise ValueError(f"negative count for {k}")
    if total_weights(placement) != model.n_params:
        raise ValueError(
            f"placement stores {total_weights(placement)} weights, model has "
            f"{model.n_params}")
    for s in arch.spaces:
        if placement.get(s.name, 0) > s.capacity_weights:
            raise ValueError(
                f"{s.name} over capacity: {placement.get(s.name, 0)} > "
                f"{s.capacity_weights}")


@dataclasses.dataclass(frozen=True)
class TaskCost:
    """Per-task timing and per-slice energy breakdown (ns / pJ)."""

    t_task_ns: float                 # makespan of one task
    t_cluster_ns: Dict[str, float]   # per-cluster busy time per task
    e_dyn_task_pj: float             # dynamic energy of one task


class EnergyModel:
    """Evaluates placements for a given (arch, model) pair."""

    def __init__(self, arch: sp.PIMArch, model: sp.ModelSpec,
                 rho: float = 1.0,
                 time_scale: Optional[Mapping[str, float]] = None):
        if rho < 1.0:
            raise ValueError("rho must be >= 1")
        self.arch = arch
        self.model = model
        self.rho = float(rho)
        # per-cluster slowdown factors (straggler mitigation feedback)
        self.time_scale = {c.name: 1.0 for c in arch.clusters}
        if time_scale:
            self.time_scale.update({k: float(v)
                                    for k, v in time_scale.items()})

    # -- per-weight characteristics of one space -------------------------
    def weight_time_ns(self, space: sp.StorageSpace) -> float:
        """Per-task module-level time contribution of ONE weight in `space`
        (already divided by the cluster's module parallelism)."""
        return (self.model.ops_per_weight * space.op_ns(self.rho)
                * self.time_scale[space.cluster] / space.n_modules)

    def weight_energy_pj(self, space: sp.StorageSpace) -> float:
        """Per-task dynamic energy of ONE weight resident in `space`."""
        return self.model.ops_per_weight * space.op_pj(self.rho)

    # -- task-level ------------------------------------------------------
    def task_cost(self, placement: Mapping[str, int]) -> TaskCost:
        t_cluster: Dict[str, float] = {}
        e_dyn = 0.0
        for c in self.arch.clusters:
            t_c = 0.0
            for s in c.spaces:
                x = placement.get(s.name, 0)
                if x:
                    t_c += x * self.weight_time_ns(s)
                    e_dyn += x * self.weight_energy_pj(s)
            t_cluster[c.name] = t_c
        return TaskCost(t_task_ns=max(t_cluster.values()),
                        t_cluster_ns=t_cluster, e_dyn_task_pj=e_dyn)

    # -- slice-level -----------------------------------------------------
    def static_energy_pj(self, placement: Mapping[str, int],
                         t_slice_ns: float, busy_ns: Mapping[str, float]
                         ) -> float:
        """Static energy of one time slice of length ``t_slice_ns`` during
        which cluster ``c`` computed for ``busy_ns[c]`` ns."""
        e = 0.0
        for c in self.arch.clusters:
            busy = min(busy_ns.get(c.name, 0.0), t_slice_ns)
            e += c.pe_static_mw_total * busy
            for s in c.spaces:
                holds = placement.get(s.name, 0) > 0
                if s.mem.volatile and holds:
                    # SRAM holding weights cannot be gated without data loss.
                    e += s.static_mw_total * t_slice_ns
                else:
                    # Gated when idle; on while the cluster computes (MRAM
                    # reads / SRAM I/O buffering).
                    e += s.static_mw_total * busy
        return e

    def slice_energy_pj(self, placement: Mapping[str, int], n_tasks: int,
                        t_slice_ns: float) -> float:
        """Total energy of a slice executing ``n_tasks`` under `placement`."""
        cost = self.task_cost(placement)
        busy = {k: v * n_tasks for k, v in cost.t_cluster_ns.items()}
        return (n_tasks * cost.e_dyn_task_pj
                + self.static_energy_pj(placement, t_slice_ns, busy))

    # -- re-placement (data movement) -------------------------------------
    def movement_cost(self, old: Mapping[str, int], new: Mapping[str, int]
                      ) -> tuple[Dict[str, float], float]:
        """Time (per destination cluster, ns) and energy (pJ) to migrate from
        placement ``old`` to ``new``.

        Weight counts are per-space; `arrivals_i = max(0, new_i - old_i)`
        weights are written into space `i` (destination write) after being
        read from a departing space of the *other* end (charged at the
        cheapest departing space's read cost, via the controller's Data
        Rearrange Buffer - paper SS.II).
        """
        arrivals = {s.name: max(0, new.get(s.name, 0) - old.get(s.name, 0))
                    for s in self.arch.spaces}
        departures = {s.name: max(0, old.get(s.name, 0) - new.get(s.name, 0))
                      for s in self.arch.spaces}
        # source read energy: drain departures in arbitrary (name) order
        # against arrivals; energy only depends on totals per space.
        e = 0.0
        for s in self.arch.spaces:
            e += departures[s.name] * s.mem.read_pj
            e += arrivals[s.name] * s.mem.write_pj
        t_move: Dict[str, float] = {}
        for c in self.arch.clusters:
            t = 0.0
            for s in c.spaces:
                t += arrivals[s.name] * s.mem.write_ns / s.n_modules
                t += departures[s.name] * s.mem.read_ns / s.n_modules
            t_move[c.name] = t
        return t_move, e

    # -- convenience -----------------------------------------------------
    def peak_placement(self, sram_only: bool = True) -> Placement:
        """Minimal-makespan placement (the paper's green/purple dots).

        ``sram_only=True``  : weights in {HP,LP}-SRAM (HH-PIM peak, green),
        ``sram_only=False`` : weights in {HP,LP}-MRAM (H-PIM style, purple).

        Generalized to any cluster count: makespan is balanced across
        all clusters (``x_c`` proportional to ``1/w_c``, remainder in
        the last cluster), which for two clusters reproduces the
        historic split exactly. A single-tier cluster (e.g. the
        far-pool of ``cxl-tier-3``, which has no "sram" space) falls
        back to its one space rather than raising.
        """
        kind = "sram" if sram_only else "mram"
        spaces_ = []
        for c in self.arch.clusters:
            try:
                spaces_.append(c.space(kind))
            except KeyError:
                if len(c.spaces) != 1:
                    raise
                spaces_.append(c.spaces[0])     # single-tier cluster
        # balance makespan: x_a * w_a = x_b * w_b = ..., sum = K
        K = self.model.n_params
        w = [self.weight_time_ns(s) for s in spaces_]
        if len(spaces_) == 1:
            return {spaces_[0].name: K}
        inv = [1.0 / wi for wi in w]
        tot_inv = sum(inv)
        pl: Placement = {}
        acc = 0
        for s, iv in zip(spaces_[:-1], inv[:-1]):
            x = min(int(round(K * iv / tot_inv)), K - acc)
            pl[s.name] = x
            acc += x
        pl[spaces_[-1].name] = K - acc
        return pl
