"""``repro.core.multipool`` - K-cluster placement combine (DESIGN.md SS.7).

Algorithm 2 of the paper combines exactly two clusters by scanning
``k_hp + k_lp = K``. :func:`combine_many` generalizes it to any cluster
count ``C`` as a min-plus (tropical) convolution fold over the
per-cluster energy tables ``E_c[r, k]`` (min energy of placing ``k``
weight groups in cluster ``c`` at row ``r`` - a time-tick row on the DP
path, a t-grid row on the closed-form path):

    (A (+) E)[r, k] = min_i A[r, i] + E[r, k - i]

Each fold keeps its argmin-``i`` trace, so the optimal per-cluster
split is recovered by backtracing from ``k = K`` through the stored
prefix counts. The final fold is evaluated only at ``k = K`` (the full
weight count), which for ``C == 2`` degenerates to exactly the pairwise
Algorithm-2 scan - the same float additions in the same order and the
same first-minimum ``argmin`` - keeping every pre-existing 1- and
2-cluster LUT byte-identical through the refactor (asserted by the
golden-digest regression suite in tests/test_multipool.py).

Complexity: one full fold is O(R * K^2) time / O(R * K) memory, and a
C-cluster combine is ``C - 2`` full folds plus the O(R * K) final
combine - linear in the cluster count, quadratic in the group count
like Algorithm 2 itself. The fold is row-local (row ``r`` of the output
depends only on row ``r`` of the inputs), so callers may slice tables
to the consulted rows *before* combining without changing any byte of
the result - `build_lut(method="dp")` exploits this to fold only the
grid's tick rows instead of all ``T + 1``.

Dtype note: inputs are combined in their own dtype (float32 DP tables,
float64 closed-form tables) - no up-cast, so the K=2 degenerate case
reproduces the historic pairwise arithmetic bit-for-bit.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

INF = float("inf")


def minplus_fold(a: np.ndarray, e: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """One min-plus convolution step with its argmin trace.

    Args:
      a: (R, K+1) prefix table - min energy of placing ``i`` groups in
         the clusters folded so far.
      e: (R, K+1) next cluster's table.

    Returns:
      out: (R, K+1) folded table ``out[r, k] = min_i a[r, i] + e[r, k-i]``.
      arg: (R, K+1) int64 argmin prefix count ``i`` (ties -> smallest
           ``i``, matching ``np.argmin``'s first-minimum rule).
    """
    if a.shape != e.shape:
        raise ValueError(f"table shapes differ: {a.shape} vs {e.shape}")
    R, K1 = a.shape
    out = np.full((R, K1), INF, dtype=a.dtype)
    arg = np.zeros((R, K1), dtype=np.int64)
    for i in range(K1):
        cand = a[:, i:i + 1] + e[:, :K1 - i]
        tail = out[:, i:]
        take = cand < tail                 # strict: first minimum wins
        tail[take] = cand[take]
        arg[:, i:][take] = i
    return out, arg


def combine_many(tables: Sequence[np.ndarray]
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Min-plus fold of ``C`` per-cluster tables with split backtrace.

    Args:
      tables: ``C`` arrays, each (R, K+1); ``tables[c][r, k]`` is the
        min energy of placing exactly ``k`` weight groups in cluster
        ``c`` at row ``r`` (+inf where infeasible).

    Returns:
      min_e:  (R,) minimum total energy of placing all ``K`` groups.
      splits: (R, C) int64 per-cluster group counts at the optimum,
        summing to ``K`` on every feasible row; all ``-1`` on
        infeasible rows.
    """
    tables = [np.asarray(t) for t in tables]
    if not tables:
        raise ValueError("combine_many needs at least one cluster table")
    R, K1 = tables[0].shape
    for t in tables[1:]:
        if t.shape != (R, K1):
            raise ValueError("cluster tables must share one (R, K+1) shape")
    C = len(tables)
    K = K1 - 1
    rows = np.arange(R)

    if C == 1:
        min_e = tables[0][:, K]
        splits = np.where(np.isfinite(min_e)[:, None], K,
                          -1).astype(np.int64)
        return min_e, splits

    # fold all but the last cluster into full-k prefix tables
    args: List[np.ndarray] = []
    F = tables[0]
    for c in range(1, C - 1):
        F, A = minplus_fold(F, tables[c])
        args.append(A)

    # final combine, evaluated only at k = K; for C == 2 this IS the
    # pairwise Algorithm-2 scan (same additions, same first-min argmin)
    cand = F + tables[C - 1][:, ::-1]      # cand[r, i] = F[r,i] + E[r,K-i]
    i_opt = np.argmin(cand, axis=1)
    min_e = cand[rows, i_opt]
    feasible = np.isfinite(min_e)

    splits = np.full((R, C), -1, dtype=np.int64)
    splits[feasible, C - 1] = K - i_opt[feasible]
    k = np.where(feasible, i_opt, 0)       # groups left in clusters 0..C-2
    for c in range(C - 2, 0, -1):
        i_prev = args[c - 1][rows, k]
        splits[feasible, c] = (k - i_prev)[feasible]
        k = np.where(feasible, i_prev, 0)
    splits[feasible, 0] = k[feasible]
    return min_e, splits
