"""``repro.core.multipool`` - K-cluster placement combine (DESIGN.md SS.7).

Algorithm 2 of the paper combines exactly two clusters by scanning
``k_hp + k_lp = K``. :func:`combine_many` generalizes it to any cluster
count ``C`` as a min-plus (tropical) convolution fold over the
per-cluster energy tables ``E_c[r, k]`` (min energy of placing ``k``
weight groups in cluster ``c`` at row ``r`` - a time-tick row on the DP
path, a t-grid row on the closed-form path):

    (A (+) E)[r, k] = min_i A[r, i] + E[r, k - i]

Each fold keeps its argmin-``i`` trace, so the optimal per-cluster
split is recovered by backtracing from ``k = K`` through the stored
prefix counts. The final fold is evaluated only at ``k = K`` (the full
weight count), which for ``C == 2`` degenerates to exactly the pairwise
Algorithm-2 scan - the same float additions in the same order and the
same first-minimum ``argmin`` - keeping every pre-existing 1- and
2-cluster LUT byte-identical through the refactor (asserted by the
golden-digest regression suite in tests/test_multipool.py).

Complexity: one full fold is O(R * K^2) time / O(R * K) memory, and a
C-cluster combine is ``C - 2`` full folds plus the O(R * K) final
combine - linear in the cluster count, quadratic in the group count
like Algorithm 2 itself. The fold is row-local (row ``r`` of the output
depends only on row ``r`` of the inputs), so callers may slice tables
to the consulted rows *before* combining without changing any byte of
the result - `build_lut(method="dp")` exploits this to fold only the
grid's tick rows instead of all ``T + 1``.

Dtype note: inputs are combined in their own dtype (float32 DP tables,
float64 closed-form tables) - no up-cast, so the K=2 degenerate case
reproduces the historic pairwise arithmetic bit-for-bit.

Two implementations of the same fold live here:

  * the numpy pair (:func:`minplus_fold` / :func:`combine_many`) - the
    historic host path, still the float64 closed-form combiner (jax
    runs float32 by default, so up-lowering it would break the
    closed-form byte contract);
  * the jax pair (:func:`minplus_fold_jnp` / :func:`combine_rows_jnp`)
    - the device path behind the fused LUT pipeline
    (:mod:`repro.kernels.lut_pipeline`). ``minplus_fold_jnp`` is written
    against pure jnp/lax primitives that lower inside a Pallas kernel
    body, so the fused kernel and the jitted ref backend literally
    share this function. Candidate generation order, strict-< updates
    and first-minimum argmin are identical to the numpy pair, so both
    produce the same float bits and the same integer splits on the
    same float32 tables (asserted by tests/test_lut_pipeline.py).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

INF = float("inf")


def minplus_fold(a: np.ndarray, e: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """One min-plus convolution step with its argmin trace.

    Args:
      a: (R, K+1) prefix table - min energy of placing ``i`` groups in
         the clusters folded so far.
      e: (R, K+1) next cluster's table.

    Returns:
      out: (R, K+1) folded table ``out[r, k] = min_i a[r, i] + e[r, k-i]``.
      arg: (R, K+1) int64 argmin prefix count ``i`` (ties -> smallest
           ``i``, matching ``np.argmin``'s first-minimum rule).
    """
    if a.shape != e.shape:
        raise ValueError(f"table shapes differ: {a.shape} vs {e.shape}")
    R, K1 = a.shape
    out = np.full((R, K1), INF, dtype=a.dtype)
    arg = np.zeros((R, K1), dtype=np.int64)
    for i in range(K1):
        cand = a[:, i:i + 1] + e[:, :K1 - i]
        tail = out[:, i:]
        take = cand < tail                 # strict: first minimum wins
        tail[take] = cand[take]
        arg[:, i:][take] = i
    return out, arg


def combine_many(tables: Sequence[np.ndarray]
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Min-plus fold of ``C`` per-cluster tables with split backtrace.

    Args:
      tables: ``C`` arrays, each (R, K+1); ``tables[c][r, k]`` is the
        min energy of placing exactly ``k`` weight groups in cluster
        ``c`` at row ``r`` (+inf where infeasible).

    Returns:
      min_e:  (R,) minimum total energy of placing all ``K`` groups.
      splits: (R, C) int64 per-cluster group counts at the optimum,
        summing to ``K`` on every feasible row; all ``-1`` on
        infeasible rows.
    """
    tables = [np.asarray(t) for t in tables]
    if not tables:
        raise ValueError("combine_many needs at least one cluster table")
    if tables[0].ndim != 2:
        raise ValueError(f"cluster 0: table must be 2-D (R, K+1), got "
                         f"shape {tables[0].shape}")
    R, K1 = tables[0].shape
    for c, t in enumerate(tables[1:], start=1):
        if t.shape != (R, K1):
            raise ValueError(
                f"cluster {c}: table shape {t.shape} disagrees with the "
                f"fold accumulator {(R, K1)} (cluster 0 sets the shared "
                f"(R, K+1) shape; the fold is row-aligned, so every "
                f"cluster must be sliced to the same rows)")
    C = len(tables)
    K = K1 - 1
    rows = np.arange(R)

    if C == 1:
        min_e = tables[0][:, K]
        splits = np.where(np.isfinite(min_e)[:, None], K,
                          -1).astype(np.int64)
        return min_e, splits

    # fold all but the last cluster into full-k prefix tables
    args: List[np.ndarray] = []
    F = tables[0]
    for c in range(1, C - 1):
        F, A = minplus_fold(F, tables[c])
        args.append(A)

    # final combine, evaluated only at k = K; for C == 2 this IS the
    # pairwise Algorithm-2 scan (same additions, same first-min argmin)
    cand = F + tables[C - 1][:, ::-1]      # cand[r, i] = F[r,i] + E[r,K-i]
    i_opt = np.argmin(cand, axis=1)
    min_e = cand[rows, i_opt]
    feasible = np.isfinite(min_e)

    splits = np.full((R, C), -1, dtype=np.int64)
    splits[feasible, C - 1] = K - i_opt[feasible]
    k = np.where(feasible, i_opt, 0)       # groups left in clusters 0..C-2
    for c in range(C - 2, 0, -1):
        i_prev = args[c - 1][rows, k]
        splits[feasible, c] = (k - i_prev)[feasible]
        k = np.where(feasible, i_prev, 0)
    splits[feasible, 0] = k[feasible]
    return min_e, splits


# ---------------------------------------------------------------------------
# jax twin of the fold - shared by the fused LUT pipeline's ref backend
# (under jit) and its Pallas kernel body (the same jnp/lax primitives
# lower in Mosaic). Lazy jax import keeps the numpy path numpy-only.
# ---------------------------------------------------------------------------


def minplus_fold_jnp(a, e):
    """jax :func:`minplus_fold`: same candidates, same order, same bits.

    Iterates the prefix count ``i`` ascending with a strict ``<`` update
    exactly like the numpy loop, so on equal inputs the returned values
    are bit-identical and the argmin trace picks the same (first)
    minimum. ``e`` is shifted by the traced ``i`` through an inf-padded
    ``dynamic_slice`` (no gathers), so this body lowers both under
    ``jax.jit`` and inside a Pallas TPU kernel.

    Returns ``(out, arg)`` with ``arg`` int32 (the numpy twin returns
    int64; both hold prefix counts ``<= K``).
    """
    import jax
    import jax.numpy as jnp

    R, K1 = a.shape
    e_pad = jnp.concatenate(
        [jnp.full((R, K1), float("inf"), a.dtype), e], axis=1)

    def body(i, carry):
        out, arg = carry
        f_col = jax.lax.dynamic_slice_in_dim(a, i, 1, axis=1)
        # g_shift[r, k] = e[r, k - i] for k >= i, else inf (the pad)
        g_shift = jax.lax.dynamic_slice_in_dim(e_pad, K1 - i, K1, axis=1)
        cand = f_col + g_shift
        take = cand < out                  # strict: first minimum wins
        return (jnp.where(take, cand, out),
                jnp.where(take, jnp.int32(i), arg))

    out0 = jnp.full((R, K1), float("inf"), a.dtype)
    arg0 = jnp.zeros((R, K1), jnp.int32)
    return jax.lax.fori_loop(0, K1, body, (out0, arg0))


def backtrace_splits_jnp(args, i_opt, feasible, K: int, C: int):
    """Vectorized split recovery from fold argmin traces (jax).

    Args:
      args: list of ``C - 2`` (R, K+1) int32 argmin traces (the middle
        folds), possibly empty.
      i_opt: (R,) int32 - argmin prefix count of the final combine.
      feasible: (R,) bool.

    Returns (R, C) int32 per-cluster counts; ``-1`` on infeasible rows.
    The gather ``args[c][r, k[r]]`` is a one-hot reduction (no gather
    op), so this helper also lowers inside the Pallas kernel body.
    """
    import jax
    import jax.numpy as jnp

    R = i_opt.shape[0]
    cols = []
    k = i_opt.astype(jnp.int32)
    last = K - k
    for c in range(C - 2, 0, -1):
        a_c = args[c - 1]
        iota = jax.lax.broadcasted_iota(jnp.int32, a_c.shape, 1)
        i_prev = jnp.sum(jnp.where(iota == k[:, None], a_c, 0), axis=1)
        cols.append((c, k - i_prev))
        k = i_prev.astype(jnp.int32)
    by_cluster = {0: k, C - 1: last}
    by_cluster.update({c: v for c, v in cols})
    splits = jnp.stack([by_cluster[c] for c in range(C)], axis=1)
    return jnp.where(feasible[:, None], splits,
                     jnp.full((R, C), -1, jnp.int32))


def combine_rows_jnp(tables):
    """jax :func:`combine_many` over stacked tables ``(C, R, K+1)``.

    Same fold order, final-combine candidates and first-minimum argmin
    as the numpy fold, so the returned ``min_e`` bits and integer
    ``splits`` match :func:`combine_many` exactly on equal float32
    inputs. This is the combine the fused LUT pipeline's ref backend
    jits; the Pallas kernel runs the same :func:`minplus_fold_jnp` /
    :func:`backtrace_splits_jnp` bodies in-kernel.
    """
    import jax.numpy as jnp

    C, R, K1 = tables.shape
    K = K1 - 1
    if C == 1:
        min_e = tables[0, :, K]
        feasible = jnp.isfinite(min_e)
        splits = jnp.where(feasible[:, None], jnp.int32(K),
                           jnp.int32(-1)).reshape(R, 1)
        return min_e, splits

    args = []
    F = tables[0]
    for c in range(1, C - 1):
        F, A = minplus_fold_jnp(F, tables[c])
        args.append(A)

    cand = F + tables[C - 1][:, ::-1]      # cand[r, i] = F[r,i] + E[r,K-i]
    i_opt = jnp.argmin(cand, axis=1).astype(jnp.int32)
    min_e = jnp.min(cand, axis=1)
    feasible = jnp.isfinite(min_e)
    splits = backtrace_splits_jnp(args, i_opt, feasible, K, C)
    return min_e, splits
