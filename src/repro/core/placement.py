"""Optimal weight-data placement for HH-PIM (paper SS.III).

Three solvers, cross-validated by the test-suite:

  * :func:`dp_min_energy`        - Algorithm 1, verbatim bottom-up DP
                                   (per-cluster, integer time ticks). Kept
                                   as the float64 reference oracle; the
                                   production ``method="dp"`` path runs
                                   the fused
                                   :mod:`repro.kernels.lut_pipeline` op
                                   (pallas / pallas_interpret / ref
                                   backends): all clusters' stage
                                   tables, the consulted-row gather and
                                   the Algorithm-2 combine in one
                                   launch, backtracing over the op's
                                   returned stage tables.
                                   ``batched=False`` keeps the per-point
                                   :mod:`repro.kernels.knapsack_dp` +
                                   host-fold loop as the byte-identity
                                   reference.
  * :func:`combine_clusters`     - Algorithm 2, combining the per-cluster
                                   tables over (k_hp, k_lp = K - k_hp);
                                   the K=2 entry point of the min-plus
                                   K-cluster fold in
                                   :mod:`repro.core.multipool`, which
                                   both LUT build paths now run so 3+
                                   pool substrates (e.g. ``cxl-tier-3``)
                                   solve through the same code.
  * :class:`ClosedFormSolver`    - beyond-paper fast path: because per-space
                                   (t_i, e_i) are uniform across weights, the
                                   per-cluster optimum lies at an endpoint of
                                   the feasible interval; exact, O(K) per
                                   t-point, and able to include the
                                   volatility-aware static terms that the
                                   paper folds into its measured results.
                                   :meth:`ClosedFormSolver.solve_clusters`
                                   solves the whole t-grid in one
                                   numpy-broadcast call (DESIGN.md SS.6).

The LUT (:class:`PlacementLUT`) is built once at application init (paper:
Algorithms 1+2 "performed only once during the application initialization
phase") and consulted per time slice; :func:`build_lut` defaults to the
batched drivers, with ``batched=False`` keeping the per-point loop as the
byte-identical reference path the equivalence suite checks against.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import spaces as sp
from repro.core.energy import EnergyModel, Placement
from repro.core.multipool import combine_many

INF = float("inf")


# ---------------------------------------------------------------------------
# Algorithm 1 - verbatim DP (per cluster)
# ---------------------------------------------------------------------------


def dp_min_energy(t_items: Sequence[int], e_items: Sequence[float],
                  T: int, K: int) -> Tuple[np.ndarray, np.ndarray]:
    """Bottom-up DP of Eq. (2) / Algorithm 1 (float64 reference oracle).

    The production ``build_lut(method="dp")`` path runs the
    :mod:`repro.kernels.knapsack_dp` op instead; this verbatim numpy
    implementation remains the cross-check the kernel tests compare
    against.

    Args:
      t_items: integer per-item time cost of each storage space (ticks).
      e_items: per-item energy cost of each storage space (pJ).
      T: time-constraint horizon in ticks.
      K: number of items (weights / weight groups) to place.

    Returns:
      dp:    (n+1, T+1, K+1) float array; ``dp[i, t, k]`` = min energy to
             place exactly ``k`` items in the first ``i`` spaces within ``t``.
      count: (n+1, T+1, K+1) int array tracing items taken in space ``i``
             at the optimum (paper's ``count`` path variable).
    """
    n = len(t_items)
    assert n == len(e_items)
    dp = np.full((n + 1, T + 1, K + 1), INF, dtype=np.float64)
    count = np.zeros((n + 1, T + 1, K + 1), dtype=np.int32)
    dp[:, :, 0] = 0.0
    for i in range(1, n + 1):
        ti, ei = int(t_items[i - 1]), float(e_items[i - 1])
        dp[i] = dp[i - 1]        # default: carry forward (t_i*k > t branch)
        count[i] = 0
        if ti > T:
            continue
        for t in range(ti, T + 1):
            # take one more item in space i (vectorized over k)
            cand = dp[i, t - ti, :-1] + ei
            take = cand < dp[i, t, 1:]
            dp[i, t, 1:] = np.where(take, cand, dp[i, t, 1:])
            count[i, t, 1:] = np.where(take, count[i, t - ti, :-1] + 1,
                                       count[i, t, 1:])
    return dp, count


def backtrace(dp: np.ndarray, count: np.ndarray,
              t_items: Sequence[int], t: int, k: int) -> List[int]:
    """Recover per-space item counts ``x_i`` from the DP tables."""
    n = dp.shape[0] - 1
    x = [0] * n
    i = n
    while k > 0 and i > 0:
        c = int(count[i, t, k])
        x[i - 1] = c
        t -= c * int(t_items[i - 1])
        k -= c
        i -= 1
    return x


def backtrace_tables(stages: np.ndarray, t_items: Sequence[int],
                     t: int, k: int) -> List[int]:
    """Recover per-space counts from stacked per-space DP tables.

    ``stages`` is the ``(n+1, T+1, K+1)`` array returned by
    ``repro.kernels.knapsack_dp.ops.knapsack_dp(..., return_stages=True)``
    (stage 0 is the k=0 base table). The recurrence is
    ``dp_i[t, k] = min(dp_{i-1}[t, k], dp_i[t - t_i, k - 1] + e_i)``, so
    at state ``(i, t, k)`` equality with the previous stage means the
    carry branch was taken (the carried value is copied bit-identically,
    so float equality is exact); otherwise one more item sits in space
    ``i``. Ties prefer the carry branch, matching the ``count`` path
    variable of the verbatim numpy DP.
    """
    n = stages.shape[0] - 1
    x = [0] * n
    i = n
    while k > 0 and i > 0:
        if stages[i, t, k] == stages[i - 1, t, k]:
            i -= 1
            continue
        x[i - 1] += 1
        t -= int(t_items[i - 1])
        k -= 1
        if t < 0:      # inconsistent table: fail loudly, not silently
            raise RuntimeError("backtrace walked below t=0")
    return x


# ---------------------------------------------------------------------------
# Algorithm 2 - combine per-cluster tables
# ---------------------------------------------------------------------------


def combine_clusters(dp_hp: np.ndarray, dp_lp: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Algorithm 2: for every t, find ``k_hp`` minimizing
    ``dp_hp[t, k_hp] + dp_lp[t, K - k_hp]``.

    The pairwise (K=2) entry point of the min-plus fold
    (:func:`repro.core.multipool.combine_many`), which degenerates to
    exactly this scan for two tables - kept as the named Algorithm-2
    API.

    Args:
      dp_hp, dp_lp: final-layer tables of shape (T+1, K+1)
        (i.e. ``dp[n/2]`` of each cluster).

    Returns:
      (min_energy[T+1], k_opt_hp[T+1]); infeasible t rows are +inf / -1.
    """
    min_e, splits = combine_many([dp_hp, dp_lp])
    return min_e, splits[:, 0]


# ---------------------------------------------------------------------------
# Closed-form per-cluster solver (beyond-paper fast path, includes statics)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClusterSolution:
    energy_pj: np.ndarray      # (K+1,) min energy for k = 0..K
    x_mram: np.ndarray         # (K+1,) weights in the cluster's MRAM
    busy_ns: np.ndarray        # (K+1,) cluster busy time at optimum


class ClosedFormSolver:
    """Exact per-cluster optimum for uniform per-weight costs.

    For ``k`` weights split ``(x_m, x_s = k - x_m)`` between MRAM and SRAM,
    time and dynamic energy are linear in ``x_m``; the static terms are a
    step function of {x_m > 0, x_s > 0}; so the optimum over each of the four
    usage-subsets lies at an interval endpoint.
    """

    def __init__(self, em: EnergyModel, group: int = 1):
        self.em = em
        self.group = group

    def _space_vectors(self, cluster: sp.ClusterSpec):
        mram = sram = None
        for s in cluster.spaces:
            if s.mem.kind == "mram":
                mram = s
            else:
                sram = s
        return mram, sram

    def _solve_far_only(self, cluster: sp.ClusterSpec,
                        mram: sp.StorageSpace, k: np.ndarray, t_budget):
        """Far-tier-only cluster (a single non-volatile space, e.g. the
        CXL pool of ``cxl-tier-3``): every group lives in the one space,
        so the per-k optimum is the feasibility-masked linear cost.

        ``t_budget`` is a scalar (per-point path) or a (P, 1) column
        (batched path); one shared code path keeps the two byte-equal.
        """
        em, g = self.em, self.group
        tw_m = em.weight_time_ns(mram) * g
        ew_m = em.weight_energy_pj(mram) * g
        cap_m = mram.capacity_weights // g
        busy = k * tw_m                                  # (K+1,)
        valid = (k <= cap_m) & (busy <= t_budget + 1e-9)
        e = k * ew_m
        # non-volatile: on only while its cluster computes
        e = e + np.where(k > 0, mram.static_mw_total * busy, 0.0)
        e = e + cluster.pe_static_mw_total * busy
        e = np.where(valid, e, INF)
        best_xm = np.where(valid, k, 0).astype(np.int64)
        best_busy = np.where(valid, busy, 0.0)
        e[..., 0] = 0.0
        best_busy[..., 0] = 0.0
        best_xm[..., 0] = 0
        return e, best_xm, best_busy

    def solve_cluster(self, cluster: sp.ClusterSpec, K: int,
                      t_budget_ns: float, static_window_ns: float
                      ) -> ClusterSolution:
        em, g = self.em, self.group
        mram, sram = self._space_vectors(cluster)
        k = np.arange(K + 1, dtype=np.float64)       # in groups
        if sram is None:
            return ClusterSolution(*self._solve_far_only(
                cluster, mram, k, t_budget_ns))
        best_e = np.full(K + 1, INF)
        best_xm = np.zeros(K + 1, dtype=np.int64)
        best_busy = np.zeros(K + 1)

        tw_s = em.weight_time_ns(sram) * g
        ew_s = em.weight_energy_pj(sram) * g
        cap_s = sram.capacity_weights // g
        if mram is not None:
            tw_m = em.weight_time_ns(mram) * g
            ew_m = em.weight_energy_pj(mram) * g
            cap_m = mram.capacity_weights // g

        def consider(x_m: np.ndarray) -> None:
            """Evaluate split (x_m, k - x_m); update running best."""
            x_s = k - x_m
            valid = (x_m >= 0) & (x_s >= 0) & (x_s <= cap_s)
            if mram is not None:
                valid &= x_m <= cap_m
            busy = (x_m * (tw_m if mram is not None else 0.0) + x_s * tw_s)
            valid &= busy <= t_budget_ns + 1e-9
            e = x_m * (ew_m if mram is not None else 0.0) + x_s * ew_s
            # statics: SRAM-on-holding for the window; MRAM/IO/PE while busy
            e = e + np.where(x_s > 0, sram.static_mw_total * static_window_ns,
                             sram.static_mw_total * busy)
            if mram is not None:
                e = e + np.where(x_m > 0, mram.static_mw_total * busy, 0.0)
            e = e + cluster.pe_static_mw_total * busy
            e = np.where(valid, e, INF)
            upd = e < best_e
            best_e[upd] = e[upd]
            best_xm[upd] = x_m[upd].astype(np.int64)
            best_busy[upd] = busy[upd]

        zeros = np.zeros(K + 1)
        if mram is None:
            consider(zeros)                          # all in SRAM
        else:
            consider(zeros)                          # all SRAM
            consider(k.copy())                       # all MRAM
            # mixed: feasible x_m interval endpoints given the time budget.
            #   busy(x_m) = x_m*tw_m + (k-x_m)*tw_s <= t_budget
            if abs(tw_m - tw_s) < 1e-12:
                pass                                 # linear in x_m is flat
            elif tw_m > tw_s:
                xm_hi = np.floor((t_budget_ns - k * tw_s) / (tw_m - tw_s))
                consider(np.clip(xm_hi, 0, k))
                consider(np.clip(xm_hi - 1, 0, k))   # guard rounding
                consider(np.minimum(np.ones(K + 1), k))
                consider(np.maximum(k - 1, zeros))
            else:
                xm_lo = np.ceil((k * tw_s - t_budget_ns) / (tw_s - tw_m))
                consider(np.clip(xm_lo, 0, k))
                consider(np.clip(xm_lo + 1, 0, k))
                consider(np.minimum(np.ones(K + 1), k))
                consider(np.maximum(k - 1, zeros))
            # capacity endpoints
            consider(np.minimum(k, float(cap_m)))
            consider(np.maximum(k - float(cap_s), zeros))
        best_e[0] = 0.0
        best_busy[0] = 0.0
        best_xm[0] = 0
        return ClusterSolution(best_e, best_xm, best_busy)

    def solve_clusters(self, cluster: sp.ClusterSpec, K: int,
                       t_budgets_ns: Sequence[float],
                       static_windows_ns: Sequence[float]
                       ) -> "BatchedClusterSolution":
        """Vectorized :meth:`solve_cluster` over a whole t-grid.

        One numpy-broadcast call evaluates every candidate split for all
        ``P = len(t_budgets_ns)`` budgets at once - the manual vmap of
        the per-point solver over the constraint axis. All arithmetic is
        the same float64 elementwise expressions in the same order, so
        row ``p`` is bit-identical to
        ``solve_cluster(cluster, K, t_budgets_ns[p], static_windows_ns[p])``
        (asserted by the batched-vs-loop equivalence suite).
        """
        em, g = self.em, self.group
        mram, sram = self._space_vectors(cluster)
        t_b = np.asarray(t_budgets_ns, np.float64).reshape(-1, 1)
        win = np.asarray(static_windows_ns, np.float64).reshape(-1, 1)
        P = t_b.shape[0]
        k = np.arange(K + 1, dtype=np.float64)       # in groups
        if sram is None:
            return BatchedClusterSolution(*self._solve_far_only(
                cluster, mram, k, t_b))
        K1 = K + 1
        best_e = np.full((P, K1), INF)
        best_xm = np.zeros((P, K1), dtype=np.int64)
        best_busy = np.zeros((P, K1))

        tw_s = em.weight_time_ns(sram) * g
        ew_s = em.weight_energy_pj(sram) * g
        cap_s = sram.capacity_weights // g
        if mram is not None:
            tw_m = em.weight_time_ns(mram) * g
            ew_m = em.weight_energy_pj(mram) * g
            cap_m = mram.capacity_weights // g

        def consider(x_m: np.ndarray) -> None:
            """Evaluate split (x_m, k - x_m) for every budget row."""
            x_s = k - x_m                  # (K1,) or (P, K1)
            valid = (x_m >= 0) & (x_s >= 0) & (x_s <= cap_s)
            if mram is not None:
                valid = valid & (x_m <= cap_m)
            busy = (x_m * (tw_m if mram is not None else 0.0) + x_s * tw_s)
            valid = valid & (busy <= t_b + 1e-9)
            e = x_m * (ew_m if mram is not None else 0.0) + x_s * ew_s
            # statics: SRAM-on-holding for the window; MRAM/IO/PE while busy
            e = e + np.where(x_s > 0, sram.static_mw_total * win,
                             sram.static_mw_total * busy)
            if mram is not None:
                e = e + np.where(x_m > 0, mram.static_mw_total * busy, 0.0)
            e = e + cluster.pe_static_mw_total * busy
            e = np.where(valid, e, INF)
            upd = e < best_e
            xb = np.broadcast_to(np.asarray(x_m, np.float64), (P, K1))
            bb = np.broadcast_to(busy, (P, K1))
            best_e[upd] = e[upd]
            best_xm[upd] = xb[upd].astype(np.int64)
            best_busy[upd] = bb[upd]

        zeros = np.zeros(K + 1)
        if mram is None:
            consider(zeros)                          # all in SRAM
        else:
            consider(zeros)                          # all SRAM
            consider(k.copy())                       # all MRAM
            # mixed: feasible x_m interval endpoints given the time budget.
            if abs(tw_m - tw_s) < 1e-12:
                pass                                 # linear in x_m is flat
            elif tw_m > tw_s:
                xm_hi = np.floor((t_b - k * tw_s) / (tw_m - tw_s))
                consider(np.clip(xm_hi, 0, k))
                consider(np.clip(xm_hi - 1, 0, k))   # guard rounding
                consider(np.minimum(np.ones(K + 1), k))
                consider(np.maximum(k - 1, zeros))
            else:
                xm_lo = np.ceil((k * tw_s - t_b) / (tw_s - tw_m))
                consider(np.clip(xm_lo, 0, k))
                consider(np.clip(xm_lo + 1, 0, k))
                consider(np.minimum(np.ones(K + 1), k))
                consider(np.maximum(k - 1, zeros))
            # capacity endpoints
            consider(np.minimum(k, float(cap_m)))
            consider(np.maximum(k - float(cap_s), zeros))
        best_e[:, 0] = 0.0
        best_busy[:, 0] = 0.0
        best_xm[:, 0] = 0
        return BatchedClusterSolution(best_e, best_xm, best_busy)


@dataclasses.dataclass
class BatchedClusterSolution:
    """Per-cluster optima for a batch of time budgets; row ``p`` of every
    array equals the :class:`ClusterSolution` of the p-th budget."""

    energy_pj: np.ndarray      # (P, K+1)
    x_mram: np.ndarray         # (P, K+1) int64
    busy_ns: np.ndarray        # (P, K+1)

    def row(self, p: int) -> ClusterSolution:
        return ClusterSolution(self.energy_pj[p], self.x_mram[p],
                               self.busy_ns[p])


# ---------------------------------------------------------------------------
# LUT builder (paper: init-time Algorithms 1+2 -> allocation_state)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LUTEntry:
    t_constraint_ns: float
    placement: Placement
    e_task_pj: float            # model-predicted per-task energy
    t_task_ns: float
    feasible: bool


def _peak_entry(em: EnergyModel, static_window_ns: Optional[float] = None
                ) -> LUTEntry:
    """Exact (ungrouped) minimal-makespan entry - the paper's green dot."""
    pl = em.peak_placement(sram_only=True)
    tc = em.task_cost(pl)
    window = static_window_ns if static_window_ns is not None else tc.t_task_ns
    e_task = tc.e_dyn_task_pj + em.static_energy_pj(pl, window,
                                                    tc.t_cluster_ns)
    return LUTEntry(tc.t_task_ns, pl, float(e_task), tc.t_task_ns, True)


def _insert_entry(entries: List[LUTEntry], e: LUTEntry) -> List[LUTEntry]:
    out = [x for x in entries if abs(x.t_constraint_ns - e.t_constraint_ns)
           > 1e-6]
    out.append(e)
    out.sort(key=lambda x: x.t_constraint_ns)
    return out


@dataclasses.dataclass
class PlacementLUT:
    arch_name: str
    model_name: str
    entries: List[LUTEntry]
    # resolved lut_pipeline backend that built the entries (None for the
    # host paths); informational only - backends are byte-identical, so
    # it never participates in equality
    backend: Optional[str] = dataclasses.field(default=None, compare=False)

    def lookup(self, t_constraint_ns: float) -> LUTEntry:
        """Largest grid point <= t_constraint (placement remains feasible)."""
        best: Optional[LUTEntry] = None
        tol = t_constraint_ns * 1e-9 + 1e-3   # relative + absolute (ns)
        for e in self.entries:
            if e.t_constraint_ns <= t_constraint_ns + tol and e.feasible:
                best = e
        if best is None:
            # infeasible budget: fall back to the fastest placement we have
            for e in self.entries:
                if e.feasible:
                    return e
            raise RuntimeError("LUT has no feasible entries")
        return best

    @property
    def min_feasible_t_ns(self) -> float:
        for e in self.entries:
            if e.feasible:
                return e.t_constraint_ns
        return INF


def _counts_to_placement(arch: sp.PIMArch, model: sp.ModelSpec,
                         counts: Mapping[str, int], group: int) -> Placement:
    """Scale group counts back to weights; absorb rounding in largest slot."""
    pl = {k: int(v) * group for k, v in counts.items()}
    diff = model.n_params - sum(pl.values())
    if diff:
        kmax = max(pl, key=lambda k: pl[k])
        pl[kmax] += diff
    return pl


# Measured per-cell cost of the BATCHED closed-form build (the lut_build
# benchmark suite records the current number): one cell = one (t-point,
# k-group, space) triple. Measured ~200 ns/cell at the default
# (64 points x 256 groups x 4 spaces) resolution; the per-point loop it
# replaced measures ~1 us/cell on the same core (the old 25 ns/cell
# default encoded only the DP inner loop, not the full per-point build,
# so it overshot the paper's 1% budget by ~40x).
BATCHED_COST_PER_CELL_NS = 200.0


def auto_resolution(model: sp.ModelSpec, t_slice_ns: float, *,
                    budget_fraction: float = 0.01,
                    cost_per_cell_ns: float = BATCHED_COST_PER_CELL_NS,
                    n_spaces: int = 4) -> Tuple[int, int]:
    """Paper SS.III.B: limit optimization resolution so the init-time LUT
    build costs at most ``budget_fraction`` of one time slice.

    The build is O(n * T * K) cells; with the measured per-cell cost of
    the batched solver (~``cost_per_cell_ns``), choose
    (n_points, k_groups) maximizing resolution within the budget.

    Returns (n_points, k_groups).
    """
    budget_cells = max(t_slice_ns * budget_fraction / cost_per_cell_ns, 64)
    # keep the T:K aspect ratio ~8:1 (time needs finer resolution than
    # group count - placements are piecewise constant in k)
    k = int(np.sqrt(budget_cells / (8.0 * n_spaces)))
    k_groups = int(min(max(k, 8), model.n_params))
    n_points = int(min(max(budget_cells / (n_spaces * k_groups), 8), 512))
    return n_points, k_groups


def _entry_fns(arch: sp.PIMArch, model: sp.ModelSpec, em: EnergyModel,
               group: int, t_slice_ns: float, static_window: str):
    """Per-build grid-point finalizers, shared by every solver driver
    (closed-form / per-point dp / fused dp / clock-grid batched) so all
    of them stay byte-identical past these lines."""
    pl_peak = em.peak_placement(sram_only=True)
    tc_peak = em.task_cost(pl_peak)

    def _window(t_c: float) -> float:
        return t_c if static_window == "t_constraint" else t_slice_ns

    def _entry(t_c: float, feasible: bool,
               counts: Mapping[str, int]) -> LUTEntry:
        window = _window(t_c)
        if feasible:
            pl = _counts_to_placement(arch, model, counts, group)
            tc = em.task_cost(pl)
            e_task = tc.e_dyn_task_pj + em.static_energy_pj(
                pl, window, tc.t_cluster_ns)
            return LUTEntry(float(t_c), pl, float(e_task), tc.t_task_ns,
                            True)
        if t_c >= tc_peak.t_task_ns:
            # grid point infeasible at group granularity but >= the exact
            # peak time: fall back to the exact peak placement
            e_task = tc_peak.e_dyn_task_pj + em.static_energy_pj(
                pl_peak, window, tc_peak.t_cluster_ns)
            return LUTEntry(float(t_c), dict(pl_peak), float(e_task),
                            tc_peak.t_task_ns, True)
        return LUTEntry(float(t_c), {}, INF, INF, False)

    return _window, _entry, tc_peak


@dataclasses.dataclass
class _DPProblem:
    """One build's Algorithm-1 discretization, ready for the fused op.

    ``t_items``/``e_items`` are (C, n_max) arrays, ragged clusters
    inert-padded with ``(t=1, e=+inf)`` - an infinite-cost space folds
    to a bitwise copy of the previous stage, so padding changes no byte
    of any table (and ``backtrace_tables`` walks padded stages through
    its carry branch). ``items`` keeps the real unpadded per-cluster
    lists for the per-point reference path.
    """

    T: int
    tick_ns: float
    t_grid: np.ndarray
    rows: np.ndarray                               # (R,) consulted tick rows
    t_items: np.ndarray                            # (C, n_max) int32
    e_items: np.ndarray                            # (C, n_max) float32
    items: Dict[str, Tuple[List[int], List[float]]]
    padded_t_lists: Dict[str, List[int]]


def _dp_problem(em: EnergyModel, arch: sp.PIMArch, group: int,
                t_slice_ns: float, dp_ticks: int,
                t_grid: np.ndarray) -> _DPProblem:
    tick_ns = t_slice_ns / float(dp_ticks)
    # The DP ceils each item's time to whole ticks, so an item spanning
    # ~1 tick is inflated by up to 100% and the DP turns conservative.
    # Edge archs put a weight group at tens of ticks; the serving pools
    # (HBM-resident weights, sub-ns per-weight times) do not - refine the
    # tick until the smallest item spans >= 8 ticks (<= 12.5% inflation),
    # capped so the O(n*T*K) tables stay affordable.
    min_item_ns = min((em.weight_time_ns(s) * group
                       for c in arch.clusters for s in c.spaces
                       if em.weight_time_ns(s) > 0), default=0.0)
    if min_item_ns and min_item_ns / tick_ns < 8:
        tick_ns = min_item_ns / 8
    T = min(int(math.ceil(t_slice_ns / tick_ns)), 16384)
    tick_ns = t_slice_ns / T
    items: Dict[str, Tuple[List[int], List[float]]] = {}
    for c in arch.clusters:
        # ceil => DP never underestimates a placement's true execution time
        t_list = [max(1, int(math.ceil(em.weight_time_ns(s) * group
                                       / tick_ns - 1e-9)))
                  for s in c.spaces]
        e_list = [em.weight_energy_pj(s) * group for s in c.spaces]
        items[c.name] = (t_list, e_list)
    n_max = max(len(c.spaces) for c in arch.clusters)
    t_arr = np.ones((len(arch.clusters), n_max), np.int32)
    e_arr = np.full((len(arch.clusters), n_max), np.inf, np.float32)
    padded: Dict[str, List[int]] = {}
    for ci, c in enumerate(arch.clusters):
        t_list, e_list = items[c.name]
        t_arr[ci, :len(t_list)] = t_list
        e_arr[ci, :len(e_list)] = e_list
        padded[c.name] = t_list + [1] * (n_max - len(t_list))
    rows = np.asarray([int(t_c / tick_ns) for t_c in t_grid], np.int32)
    return _DPProblem(T, tick_ns, t_grid, rows, t_arr, e_arr, items, padded)


def _dp_entries(arch: sp.PIMArch, prob: _DPProblem, stages: np.ndarray,
                min_e: np.ndarray, splits: np.ndarray,
                entry_fn) -> List[LUTEntry]:
    """Finalize every grid point from one variant's fused-op results:
    per-cluster stage-table backtrace at that cluster's split share,
    then the shared entry finalizer."""
    entries: List[LUTEntry] = []
    for i, t_c in enumerate(prob.t_grid):
        t_ticks = int(prob.rows[i])
        feasible = bool(np.isfinite(min_e[i]))
        counts: Dict[str, int] = {}
        if feasible:
            for ci, (c, k_c) in enumerate(zip(arch.clusters, splits[i])):
                xs = backtrace_tables(stages[ci],
                                      prob.padded_t_lists[c.name],
                                      t_ticks, int(k_c))
                for s, x in zip(c.spaces, xs):
                    counts[s.name] = x
        entries.append(entry_fn(t_c, feasible, counts))
    return entries


def build_lut(arch: sp.PIMArch, model: sp.ModelSpec, *,
              t_slice_ns: float, n_points: int = 64, rho: float = 1.0,
              method: str = "closed_form", k_groups: int = 256,
              static_window: str = "t_constraint",
              em: Optional[EnergyModel] = None, batched: bool = True,
              dp_backend: str = "auto", lut_backend: str = "auto",
              dp_ticks: int = 2048) -> PlacementLUT:
    """Construct ``allocation_state`` - the init-time placement LUT.

    ``method="closed_form"`` uses :class:`ClosedFormSolver` (exact, with
    statics); ``method="dp"`` runs Algorithms 1+2 on the dynamic energies
    through the fused :mod:`repro.kernels.lut_pipeline` op - per-cluster
    stage tables, consulted-row gather and the min-plus combine with
    argmin backtrace in one device launch. ``lut_backend`` selects
    auto / pallas / pallas_interpret / ref for that launch (``auto``
    defers to ``dp_backend`` for backward compatibility, then to the
    ``REPRO_LUT_BACKEND`` environment override).

    ``batched=True`` (default) solves the whole t-grid in one vectorized
    pass per cluster; ``batched=False`` keeps the per-point loop (the
    unfused :mod:`repro.kernels.knapsack_dp` op plus the host numpy
    fold), which must produce byte-identical LUTs (asserted by the
    equivalence suites in tests/test_api.py and
    tests/test_lut_pipeline.py). An explicit ``em`` (e.g. with straggler
    ``time_scale``) overrides the default model.
    """
    em = em or EnergyModel(arch, model, rho=rho)
    K = model.n_params
    group = max(1, math.ceil(K / k_groups))
    Kg = math.ceil(K / group)
    _window, _entry, tc_peak = _entry_fns(arch, model, em, group,
                                          t_slice_ns, static_window)
    t_grid = np.linspace(t_slice_ns / n_points, t_slice_ns, n_points)
    # always include the exact peak-performance point (the paper's green
    # dot), otherwise full-load lookups land on a coarser, slower entry.
    if tc_peak.t_task_ns <= t_slice_ns:
        t_grid = np.unique(np.concatenate([t_grid, [tc_peak.t_task_ns]]))

    def _split_counts(sols: Mapping[str, ClusterSolution],
                      split: Sequence[int]) -> Dict[str, int]:
        """Per-space group counts from a per-cluster split (the
        :func:`repro.core.multipool.combine_many` backtrace row)."""
        counts: Dict[str, int] = {}
        for c, k_c in zip(arch.clusters, split):
            sol = sols[c.name]
            ksel = int(k_c)
            xm = int(sol.x_mram[ksel])
            for s in c.spaces:
                counts[s.name] = xm if s.mem.kind == "mram" else ksel - xm
        return counts

    entries: List[LUTEntry] = []
    if method == "closed_form":
        solver = ClosedFormSolver(em, group=group)
        if batched:
            windows = np.asarray([_window(t_c) for t_c in t_grid])
            batch = {c.name: solver.solve_clusters(c, Kg, t_grid, windows)
                     for c in arch.clusters}
            # K-pool optimum over the simplex of per-cluster splits: the
            # min-plus fold over every cluster's (P, K+1) energy table
            min_e, splits = combine_many(
                [batch[c.name].energy_pj for c in arch.clusters])
            for i, t_c in enumerate(t_grid):
                feasible = bool(np.isfinite(min_e[i]))
                counts: Dict[str, int] = {}
                if feasible:
                    sols = {name: b.row(i) for name, b in batch.items()}
                    counts = _split_counts(sols, splits[i])
                entries.append(_entry(t_c, feasible, counts))
        else:
            for t_c in t_grid:
                sols = {c.name: solver.solve_cluster(c, Kg, t_c,
                                                     _window(t_c))
                        for c in arch.clusters}
                m_e, s_row = combine_many(
                    [sols[c.name].energy_pj[None, :]
                     for c in arch.clusters])
                feasible = bool(np.isfinite(m_e[0]))
                counts = _split_counts(sols, s_row[0]) if feasible else {}
                entries.append(_entry(t_c, feasible, counts))
        entries = _insert_entry(entries, _peak_entry(
            em, None if static_window == "t_constraint" else t_slice_ns))
        return PlacementLUT(arch.name, model.name, entries)

    if method != "dp":
        raise ValueError(method)

    # -- Algorithm 1 + 2 path ----------------------------------------------
    prob = _dp_problem(em, arch, group, t_slice_ns, dp_ticks, t_grid)

    if batched:
        # Fused pipeline: every cluster's stage tables, the consulted
        # t-grid row gather AND the min-plus combine with argmin
        # backtrace in ONE device launch (lazy import keeps the
        # closed-form path numpy-only). The fold is row-local, so
        # combining only the consulted tick rows is byte-identical to
        # combining the full tables and indexing after - the per-point
        # path below does exactly that against the same tables.
        from repro.kernels.lut_pipeline.ops import lut_build as fused_build
        from repro.kernels.lut_pipeline.ops import resolve_backend
        backend = resolve_backend(
            lut_backend if lut_backend != "auto" else dp_backend)
        stages, min_e_all, splits_all = fused_build(
            prob.t_items[None], prob.e_items[None], prob.T, Kg, prob.rows,
            backend=backend)
        entries = _dp_entries(arch, prob, np.asarray(stages[0]),
                              np.asarray(min_e_all[0]),
                              np.asarray(splits_all[0]), _entry)
        entries = _insert_entry(entries, _peak_entry(
            em, None if static_window == "t_constraint" else t_slice_ns))
        return PlacementLUT(arch.name, model.name, entries,
                            backend=backend)

    # Per-point reference loop: the unfused knapsack op plus the host
    # numpy fold per grid point - the byte-identity anchor the fused
    # path is asserted against.
    from repro.kernels.knapsack_dp.ops import knapsack_dp

    stage_tables: Dict[str, np.ndarray] = {}
    for c in arch.clusters:
        t_list, e_list = prob.items[c.name]
        stage_tables[c.name] = np.asarray(knapsack_dp(
            t_list, e_list, prob.T, Kg, backend=dp_backend,
            return_stages=True))
    finals = [stage_tables[c.name][-1] for c in arch.clusters]
    for i, t_c in enumerate(prob.t_grid):
        t_ticks = int(prob.rows[i])
        m_e, s_row = combine_many([f[t_ticks:t_ticks + 1] for f in finals])
        min_e, split = m_e[0], s_row[0]
        feasible = bool(np.isfinite(min_e))
        counts: Dict[str, int] = {}
        if feasible:
            # per-cluster stage-table backtrace at that cluster's share
            for c, k_c in zip(arch.clusters, split):
                xs = backtrace_tables(stage_tables[c.name],
                                      prob.items[c.name][0],
                                      t_ticks, int(k_c))
                for s, x in zip(c.spaces, xs):
                    counts[s.name] = x
        entries.append(_entry(t_c, feasible, counts))
    entries = _insert_entry(entries, _peak_entry(
        em, None if static_window == "t_constraint" else t_slice_ns))
    return PlacementLUT(arch.name, model.name, entries)


def build_lut_grid(ems: Sequence[EnergyModel], *, t_slice_ns: float,
                   n_points: int = 64, method: str = "dp",
                   k_groups: int = 256,
                   static_window: str = "t_constraint",
                   dp_backend: str = "auto", lut_backend: str = "auto",
                   dp_ticks: int = 2048) -> List[PlacementLUT]:
    """Batched LUT builds across substrate variants (DESIGN.md SS.6/SS.10).

    For a DVFS clock grid every variant shares the model and cluster
    topology but scales its energies/times, so the Algorithm-1 + 2
    pipeline is the same shape per variant. Variants whose DP
    discretization agrees (same tick horizon ``T``, group count and
    grid size) are stacked on the fused op's variant axis and solved in
    ONE device launch; the rest fall back to one launch each. Each
    returned LUT is byte-identical to ``build_lut(em.arch, em.model,
    em=em, method="dp", ...)`` for the matching variant.

    Non-dp methods delegate to :func:`build_lut` per variant.
    """
    if method != "dp":
        return [build_lut(em.arch, em.model, t_slice_ns=t_slice_ns,
                          n_points=n_points, method=method,
                          k_groups=k_groups, static_window=static_window,
                          em=em, dp_backend=dp_backend,
                          lut_backend=lut_backend, dp_ticks=dp_ticks)
                for em in ems]
    from repro.kernels.lut_pipeline.ops import lut_build as fused_build
    from repro.kernels.lut_pipeline.ops import resolve_backend
    backend = resolve_backend(
        lut_backend if lut_backend != "auto" else dp_backend)

    preps = []
    for em in ems:
        arch, model = em.arch, em.model
        K = model.n_params
        group = max(1, math.ceil(K / k_groups))
        Kg = math.ceil(K / group)
        _window, _entry, tc_peak = _entry_fns(arch, model, em, group,
                                              t_slice_ns, static_window)
        t_grid = np.linspace(t_slice_ns / n_points, t_slice_ns, n_points)
        if tc_peak.t_task_ns <= t_slice_ns:
            t_grid = np.unique(np.concatenate([t_grid,
                                               [tc_peak.t_task_ns]]))
        prob = _dp_problem(em, arch, group, t_slice_ns, dp_ticks, t_grid)
        preps.append((em, arch, Kg, prob, _entry))

    groups: Dict[tuple, List[int]] = {}
    for idx, (em, arch, Kg, prob, _entry) in enumerate(preps):
        key = (prob.T, Kg, len(prob.rows), prob.t_items.shape)
        groups.setdefault(key, []).append(idx)

    luts: List[Optional[PlacementLUT]] = [None] * len(preps)
    for (T, Kg_g, _, _), idxs in groups.items():
        stages, min_e, splits = fused_build(
            np.stack([preps[i][3].t_items for i in idxs]),
            np.stack([preps[i][3].e_items for i in idxs]),
            T, Kg_g, np.stack([preps[i][3].rows for i in idxs]),
            backend=backend)
        stages = np.asarray(stages)
        min_e = np.asarray(min_e)
        splits = np.asarray(splits)
        for v, i in enumerate(idxs):
            em, arch, Kg, prob, _entry = preps[i]
            entries = _dp_entries(arch, prob, stages[v], min_e[v],
                                  splits[v], _entry)
            entries = _insert_entry(entries, _peak_entry(
                em, None if static_window == "t_constraint"
                else t_slice_ns))
            luts[i] = PlacementLUT(arch.name, em.model.name, entries,
                                   backend=backend)
    return luts
