"""Time-slice scheduler: the runtime half of the paper's SS.III strategy.

Tasks generated during slice ``s-1`` are buffered and must complete inside
slice ``s`` (operational latency <= 2T). Per slice the scheduler derives
``t_constraint = (T - movement_overhead) / n_tasks``, consults the placement
LUT, migrates weights if the optimum changed, and executes the backlog.

The same class doubles as the straggler-mitigation feedback loop of the
TPU-serving adaptation: an observed per-cluster slowdown factor rescales the
effective per-weight times before lookup, so a degraded pool automatically
receives a smaller shard next slice.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro import obs
from repro.core import spaces as sp
from repro.core.compiler import slowdown_signature
from repro.core.energy import EnergyModel, Placement
from repro.core.placement import PlacementLUT
from repro.core.solvers import PlacementSolver, make_solver


@dataclasses.dataclass
class SliceReport:
    slice_idx: int
    n_tasks: int
    t_constraint_ns: float
    placement: Placement
    moved_weights: int
    t_move_ns: float
    e_move_pj: float
    t_exec_ns: float             # n_executed * t_task
    e_dyn_pj: float
    e_static_pj: float
    deadline_met: bool
    # tasks actually run this slice; < n_tasks only under capacity capping
    # (fleet serving), where the remainder carries over to the next slice.
    n_executed: Optional[int] = None
    # DVFS clock the online controller chose for this slice; None when
    # the scheduler runs at a static operating point (no controller).
    clock: Optional[float] = None

    @property
    def n_done(self) -> int:
        return self.n_tasks if self.n_executed is None else self.n_executed

    @property
    def t_task_ns(self) -> float:
        return self.t_exec_ns / self.n_done if self.n_done else 0.0

    @property
    def energy_pj(self) -> float:
        return self.e_dyn_pj + self.e_static_pj + self.e_move_pj


class TimeSliceScheduler:
    def __init__(self, *args, **kw):
        # The PR 2 keyword-threaded constructor finished its one-release
        # deprecation window and is gone.
        raise TypeError(
            "direct TimeSliceScheduler(arch, model, ...) construction was "
            "removed; build through repro.api.scheduler(substrate_name, "
            "...) or TimeSliceScheduler.from_substrate(substrate, ...) "
            "(DESIGN.md SS.5)")

    @classmethod
    def from_substrate(cls, substrate, workload=None, *,
                       t_slice_ns: Optional[float] = None,
                       rho: Optional[float] = None,
                       solver=None,
                       lut: Optional[PlacementLUT] = None,
                       initial_placement: Optional[Placement] = None,
                       lut_points: Optional[int] = None,
                       compiler=None, dvfs=None) -> "TimeSliceScheduler":
        """Canonical constructor: resolve everything from a
        :class:`~repro.core.substrate.Substrate` (duck-typed), letting
        callers override slice length, reuse factor, solver and LUT.
        A shared :class:`~repro.core.compiler.PlacementCompiler` makes
        LUT (re)builds - including straggler-rescaling rebuilds - hit a
        fleet-wide cache instead of this engine's private one.

        ``dvfs`` attaches the online DVFS controller (DESIGN.md SS.10):
        ``True`` solves over the substrate TechModel's default clock
        grid, an int sets the grid size, a sequence gives explicit clock
        points, and a prebuilt
        :class:`~repro.core.techmodel.DVFSController` is shared as-is
        (fleet workers of one shape share one controller). Each slice
        then picks the energy-minimal (placement, clock) pair instead of
        running at the substrate's static ``lp_clock``."""
        model = substrate.model_spec(workload)
        rho = substrate.rho if rho is None else rho
        if t_slice_ns is None:
            t_slice_ns = substrate.default_t_slice_ns(model, rho=rho)
        sol = make_solver(solver or substrate.solver)
        self = cls.__new__(cls)
        self._setup(substrate.arch, model, t_slice_ns=t_slice_ns, rho=rho,
                    lut=lut, initial_placement=initial_placement,
                    lut_points=(substrate.lut_points if lut_points is None
                                else lut_points),
                    solver=sol,
                    static_window=getattr(substrate, "static_window",
                                          "t_constraint"),
                    compiler=compiler,
                    variant_key=substrate.variant_key())
        if dvfs is not None and dvfs is not False:
            from repro.core.techmodel import DVFSController
            if isinstance(dvfs, DVFSController):
                ctrl = dvfs
            else:
                kw = {}
                if isinstance(dvfs, int) and not isinstance(dvfs, bool):
                    kw["n_clocks"] = dvfs
                elif not isinstance(dvfs, bool):
                    kw["clocks"] = tuple(dvfs)
                ctrl = DVFSController(
                    substrate, model, t_slice_ns=self.t_slice_ns, rho=rho,
                    solver=sol, lut_points=self.lut_points,
                    compiler=compiler, **kw)
                ctrl.prepare()
            self.dvfs = ctrl
        return self

    def _setup(self, arch: sp.PIMArch, model: sp.ModelSpec, *,
               t_slice_ns: float, rho: float,
               lut: Optional[PlacementLUT],
               initial_placement: Optional[Placement],
               lut_points: int,
               solver: Optional[PlacementSolver] = None,
               static_window: str = "t_constraint",
               compiler=None, variant_key: Optional[tuple] = None) -> None:
        self.arch = arch
        self.model = model
        self.t_slice_ns = float(t_slice_ns)
        self.rho = rho
        self.lut_points = lut_points
        self.static_window = static_window
        self.compiler = compiler
        self.variant_key = variant_key or (arch.name,)
        self.solver = solver if solver is not None \
            else make_solver("closed-form")
        # online DVFS controller (repro.core.techmodel); None = static
        # operating point. Attached by from_substrate(dvfs=...) or by
        # api.fleet, which shares one controller per engine shape.
        self.dvfs = None
        self.em = EnergyModel(arch, model, rho=rho)
        # slowdown must exist before the cache prime: the lut property
        # looks the cache up under the populated slowdown signature.
        self.slowdown: Dict[str, float] = {c.name: 1.0
                                           for c in self.arch.clusters}
        self._lut_cache: Dict[tuple, PlacementLUT] = {}
        if lut is not None:
            self._lut_cache[self._slowdown_key()] = lut
        if initial_placement is None:
            initial_placement = self.solver.initial_placement(self.em)
        self.placement: Placement = dict(
            initial_placement or self.em.peak_placement(sram_only=True))
        self._idx = 0

    # -- straggler feedback ------------------------------------------------
    def observe_slowdown(self, cluster: str, factor: float) -> None:
        """Report that `cluster` currently runs `factor`x slower than spec.

        The next slice re-solves placement against the degraded timing model
        (LUT rebuilt and cached per slowdown signature), so the straggling
        pool automatically receives a smaller weight shard.
        """
        if factor < 1.0:
            raise ValueError("slowdown factor must be >= 1")
        self.slowdown[cluster] = float(factor)
        self.em = EnergyModel(self.arch, self.model, rho=self.rho,
                              time_scale=self.slowdown)

    def _slowdown_key(self) -> tuple:
        # shared helper: must stay keyed identically to the compiler's
        # cache for straggler rebuilds to hit the fleet-wide entry
        return slowdown_signature(getattr(self, "slowdown", {}))

    @property
    def lut(self) -> PlacementLUT:
        key = self._slowdown_key()
        if key not in self._lut_cache:
            if obs.enabled():
                obs.counter("sched.lut.miss")
            if self.compiler is not None:
                # fleet-wide build service: engines of the same shape and
                # slowdown signature share one build
                self._lut_cache[key] = self.compiler.lut(
                    self.em, solver=self.solver,
                    t_slice_ns=self.t_slice_ns, n_points=self.lut_points,
                    static_window=self.static_window,
                    variant_key=self.variant_key)
            else:
                with obs.span("sched.lut_build", "scheduler",
                              arch=self.arch.name, solver=self.solver.name,
                              n_points=self.lut_points):
                    self._lut_cache[key] = self.solver.build_lut(
                        self.em, t_slice_ns=self.t_slice_ns,
                        n_points=self.lut_points,
                        static_window=self.static_window)
        elif obs.enabled():
            obs.counter("sched.lut.hit")
        return self._lut_cache[key]

    def stage_cost(self, n_tasks: int) -> "tuple[float, float]":
        """Read-only LUT consultation for stage co-scheduling
        (:mod:`repro.fleet.dag`): the ``(t_task_ns, e_dyn_task_pj)``
        this engine would pay per task if ``n_tasks`` were due in one
        slice. Shares :attr:`lut` (the SS.6 variant-key cache), so the
        query costs zero builds beyond the engine's own LUT and never
        mutates scheduler state (no migration, no report)."""
        entry = self.lut.lookup(self.t_slice_ns / max(n_tasks, 1))
        cost = self.em.task_cost(entry.placement)
        return cost.t_task_ns, cost.e_dyn_task_pj

    # -- one slice ----------------------------------------------------------
    def step(self, n_tasks: int, *, lookup_tasks: Optional[int] = None,
             cap_to_capacity: bool = False) -> SliceReport:
        """Execute one time slice with ``n_tasks`` buffered tasks.

        ``lookup_tasks`` (fleet forecasting hook): consult the placement LUT
        as if this many tasks were due, instead of the actual backlog. A
        forecaster predicting next-slice load can thereby trigger *proactive*
        weight migration during a quiet slice, before the burst lands.

        ``cap_to_capacity``: execute only as many tasks as fit inside the
        slice under the chosen placement (``n_executed`` in the report); the
        caller carries the remainder into the next slice. Default keeps the
        paper semantics (whole backlog runs, deadline possibly missed).
        """
        _obs = obs.enabled()
        _t0 = obs.now_ns() if _obs else 0
        T = self.t_slice_ns
        n_plan = max(lookup_tasks if lookup_tasks is not None else n_tasks, 1)
        clock = None
        if self.dvfs is not None:
            # online DVFS: the controller picks the energy-minimal
            # (placement, clock) grid point for this slice's plan; the
            # slice then runs entirely under that point's physics.
            clock, em, lut, _ = self.dvfs.select(n_plan,
                                                 slowdown=self.slowdown)
        else:
            em = self.em
            lut = self.lut

        # pass 1: ignore movement; pass 2: subtract its overhead (paper:
        # "the calculation of t_constraint at runtime incorporates the data
        # movement overhead").
        entry = lut.lookup(T / n_plan)
        t_move_c, e_move = em.movement_cost(self.placement,
                                            entry.placement)
        t_move = max(t_move_c.values(), default=0.0)
        if t_move > 0:
            entry2 = lut.lookup(max(T - t_move, 0.0) / n_plan)
            t_move_c2, e_move2 = em.movement_cost(self.placement,
                                                  entry2.placement)
            t_move2 = max(t_move_c2.values(), default=0.0)
            if n_plan * entry2.t_task_ns + t_move2 <= T + 1e-9:
                entry, t_move, e_move = entry2, t_move2, e_move2
            # if even the refined choice cannot absorb the migration this
            # slice, keep the current placement when it meets the deadline
            # on its own ("no inference delay due to data movement").
            elif (n_plan * em.task_cost(self.placement).t_task_ns
                  <= T + 1e-9):
                entry = None

        if entry is None:
            new_placement = dict(self.placement)
            t_move, e_move = 0.0, 0.0
        else:
            new_placement = dict(entry.placement)
        moved = sum(max(0, new_placement.get(k, 0) - self.placement.get(k, 0))
                    for k in {*new_placement, *self.placement})

        cost = em.task_cost(new_placement)
        n_run = n_tasks
        if cap_to_capacity and cost.t_task_ns > 0:
            capacity = int((T - t_move + 1e-6) // cost.t_task_ns)
            n_run = min(n_tasks, max(capacity, 0))
        t_exec = n_run * cost.t_task_ns
        busy = {c: t * n_run for c, t in cost.t_cluster_ns.items()}
        e_dyn = n_run * cost.e_dyn_task_pj
        e_static = em.static_energy_pj(new_placement, T, busy)
        deadline_met = (n_tasks * cost.t_task_ns + t_move) <= T + 1e-6

        # t_constraint reflects the load the LUT was actually consulted
        # with (the forecast under lookup_tasks), so reports explain the
        # recorded placement
        rep = SliceReport(self._idx, n_tasks, T / n_plan,
                          new_placement, moved, t_move, e_move, t_exec,
                          e_dyn, e_static, deadline_met, n_executed=n_run,
                          clock=clock)
        self.placement = new_placement
        self._idx += 1
        if _obs:
            if clock is not None:
                obs.gauge("sched.dvfs.clock", clock)
            # the slice span carries the full SliceReport so a Perfetto
            # timeline attributes every missed deadline to its placement
            obs.complete("sched.slice", _t0, cat="scheduler", args={
                "slice": rep.slice_idx, "n_tasks": n_tasks,
                "n_executed": n_run, "lookup_tasks": n_plan,
                "t_constraint_ns": rep.t_constraint_ns,
                "t_move_ns": t_move, "t_exec_ns": t_exec,
                "moved_weights": moved, "e_dyn_pj": e_dyn,
                "e_static_pj": e_static, "e_move_pj": e_move,
                "deadline_met": deadline_met, "clock": clock,
                "placement": dict(new_placement)})
            if moved:
                obs.instant("sched.migration", cat="scheduler",
                            args={"slice": rep.slice_idx,
                                  "moved_weights": moved,
                                  "t_move_ns": t_move})
        return rep

    def run(self, tasks_per_slice: List[int]) -> List[SliceReport]:
        return [self.step(n) for n in tasks_per_slice]


class FixedPlacementScheduler:
    """Comparison-group runtime: placement never changes (Baseline-,
    Heterogeneous- and Hybrid-PIM in Table I)."""

    def __init__(self, arch: sp.PIMArch, model: sp.ModelSpec, *,
                 t_slice_ns: float, placement: Placement, rho: float = 1.0):
        self.arch = arch
        self.model = model
        self.t_slice_ns = float(t_slice_ns)
        self.em = EnergyModel(arch, model, rho=rho)
        self.placement = dict(placement)
        self._idx = 0

    def step(self, n_tasks: int) -> SliceReport:
        T = self.t_slice_ns
        cost = self.em.task_cost(self.placement)
        busy = {c: t * n_tasks for c, t in cost.t_cluster_ns.items()}
        e_dyn = n_tasks * cost.e_dyn_task_pj
        e_static = self.em.static_energy_pj(self.placement, T, busy)
        rep = SliceReport(self._idx, n_tasks, T / max(n_tasks, 1),
                          dict(self.placement), 0, 0.0, 0.0,
                          n_tasks * cost.t_task_ns, e_dyn, e_static,
                          n_tasks * cost.t_task_ns <= T + 1e-6)
        self._idx += 1
        return rep

    def run(self, tasks_per_slice: List[int]) -> List[SliceReport]:
        return [self.step(n) for n in tasks_per_slice]
