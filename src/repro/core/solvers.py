"""Pluggable placement solvers behind one strategy interface.

Every solver turns an :class:`~repro.core.energy.EnergyModel` plus a slice
length into a :class:`~repro.core.placement.PlacementLUT`, so schedulers,
benchmarks and fleets can swap the optimization strategy by name without
re-threading ``(arch, model, em, ...)`` tuples:

  * ``"closed-form"`` - exact per-cluster endpoint solver with statics
    (:class:`repro.core.placement.ClosedFormSolver`), the default;
    solves the whole t-grid in one vectorized pass (DESIGN.md SS.6).
  * ``"dp"``          - Algorithms 1+2 (tick-quantized DP) on the
    :mod:`repro.kernels.knapsack_dp` op (pallas on TPU, jitted ref on
    CPU, ``pallas_interpret`` for kernel-path CI coverage).
  * ``"fixed-baseline"`` / ``"fixed-hetero"`` / ``"fixed-hybrid"`` - the
    Table I comparison policies as *degenerate* solvers: one placement for
    every constraint, packaged as a single-entry LUT so they can be
    benchmarked through the same builder as the real solvers.

Adding a solver is one :func:`register_solver` call; see DESIGN.md SS.5.

The DVFS clock axis (DESIGN.md SS.10) is orthogonal to the solver
registry: the online controller (:mod:`repro.core.techmodel`) builds one
LUT per clock grid point *through* whichever dynamic solver the
substrate names, then picks among the per-point LUTs at runtime -- so a
new solver composes with the clock axis for free, and a new TechModel
never touches solver code.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Union

from repro.core.energy import EnergyModel, Placement
from repro.core.placement import LUTEntry, PlacementLUT, build_lut


class PlacementSolver:
    """Strategy interface: (EnergyModel, t_slice) -> PlacementLUT."""

    name: str
    #: True for degenerate solvers whose placement never changes; the api
    #: layer runs these through FixedPlacementScheduler (no movement logic).
    fixed: bool

    def build_lut(self, em: EnergyModel, *, t_slice_ns: float,
                  n_points: int = 64, k_groups: int = 256,
                  static_window: str = "t_constraint") -> PlacementLUT:
        raise NotImplementedError

    def initial_placement(self, em: EnergyModel) -> Optional[Placement]:
        """Placement to boot a scheduler with (None = scheduler default)."""
        return None


@dataclasses.dataclass
class LUTMethodSolver(PlacementSolver):
    """Dynamic solver backed by :func:`repro.core.placement.build_lut`.

    ``batched`` selects the vectorized whole-t-grid drivers (DESIGN.md
    SS.6, the default) vs the per-point reference loop - byte-identical
    output either way; ``lut_backend`` picks the fused
    :mod:`repro.kernels.lut_pipeline` backend for ``method="dp"``
    (auto / pallas / pallas_interpret / ref), with ``dp_backend``
    kept as the legacy alias it defers to (and as the ``knapsack_dp``
    backend of the unbatched reference loop)."""

    name: str
    method: str                     # build_lut method key
    fixed: bool = False
    batched: bool = True
    dp_backend: str = "auto"
    lut_backend: str = "auto"

    def build_lut(self, em: EnergyModel, *, t_slice_ns: float,
                  n_points: int = 64, k_groups: int = 256,
                  static_window: str = "t_constraint") -> PlacementLUT:
        return build_lut(em.arch, em.model, t_slice_ns=t_slice_ns,
                         n_points=n_points, rho=em.rho, method=self.method,
                         k_groups=k_groups, static_window=static_window,
                         em=em, batched=self.batched,
                         dp_backend=self.dp_backend,
                         lut_backend=self.lut_backend)


@dataclasses.dataclass
class FixedPolicySolver(PlacementSolver):
    """Degenerate solver: one fixed placement for every time constraint
    (Baseline-/Heterogeneous-/Hybrid-PIM of Table I)."""

    name: str
    policy: Callable[[EnergyModel], Placement]
    fixed: bool = True

    def placement(self, em: EnergyModel) -> Placement:
        return dict(self.policy(em))

    def initial_placement(self, em: EnergyModel) -> Placement:
        return self.placement(em)

    def build_lut(self, em: EnergyModel, *, t_slice_ns: float,
                  n_points: int = 64, k_groups: int = 256,
                  static_window: str = "t_constraint") -> PlacementLUT:
        pl = self.placement(em)
        tc = em.task_cost(pl)
        e_task = tc.e_dyn_task_pj + em.static_energy_pj(
            pl, tc.t_task_ns, tc.t_cluster_ns)
        entry = LUTEntry(tc.t_task_ns, pl, float(e_task), tc.t_task_ns, True)
        return PlacementLUT(em.arch.name, em.model.name, [entry])


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

SolverFactory = Callable[[], PlacementSolver]
SOLVERS: Dict[str, SolverFactory] = {}

_ALIASES = {"closed_form": "closed-form"}   # legacy build_lut method name


def register_solver(name: str, factory: SolverFactory) -> None:
    SOLVERS[name] = factory


def make_solver(name: Union[str, PlacementSolver]) -> PlacementSolver:
    """Resolve a solver by registry name (instances pass through)."""
    if isinstance(name, PlacementSolver):
        return name
    key = _ALIASES.get(name, name)
    if key not in SOLVERS:
        raise ValueError(
            f"unknown solver {name!r}; one of {sorted(SOLVERS)}")
    return SOLVERS[key]()


register_solver("closed-form",
                lambda: LUTMethodSolver("closed-form", "closed_form"))
register_solver("dp", lambda: LUTMethodSolver("dp", "dp"))

# The three fixed comparison policies. All reduce to a peak placement of
# the matching arch (baseline/hetero: makespan-balanced SRAM; hybrid:
# MRAM-resident weights, SRAM as I/O buffer), which is exactly what
# repro.core.baselines computes policy-by-policy.
register_solver("fixed-baseline", lambda: FixedPolicySolver(
    "fixed-baseline", lambda em: em.peak_placement(sram_only=True)))
register_solver("fixed-hetero", lambda: FixedPolicySolver(
    "fixed-hetero", lambda em: em.peak_placement(sram_only=True)))
register_solver("fixed-hybrid", lambda: FixedPolicySolver(
    "fixed-hybrid", lambda em: em.peak_placement(sram_only=False)))
