"""Hardware specification of HH-PIM and the comparison PIM architectures.

All constants come verbatim from the paper:
  - Table I   : module configurations of the four evaluated architectures.
  - Table III : read/write/PE latencies (ns) at 1.2 V (HP) and 0.8 V (LP).
  - Table IV  : TinyML benchmark model characteristics.
  - Table V   : dynamic (read/write) and static power (mW) per memory type.

Units used throughout `repro.core`:
  time   : nanoseconds (ns)
  power  : milliwatts  (mW)
  energy : picojoules  (pJ)   [mW x ns = pJ]
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

# ---------------------------------------------------------------------------
# Memory / PE primitives
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MemorySpec:
    """One memory bank type inside a PIM module."""

    kind: str            # "mram" | "sram"
    read_ns: float
    write_ns: float
    read_mw: float       # dynamic power while reading
    write_mw: float      # dynamic power while writing
    static_mw: float     # leakage per 64 kB bank
    volatile: bool       # True => loses data when power-gated
    capacity_bytes: int = 64 * 1024

    @property
    def read_pj(self) -> float:
        return self.read_ns * self.read_mw

    @property
    def write_pj(self) -> float:
        return self.write_ns * self.write_mw


@dataclasses.dataclass(frozen=True)
class PESpec:
    op_ns: float         # latency of one MAC
    dyn_mw: float
    static_mw: float

    @property
    def op_pj(self) -> float:
        return self.op_ns * self.dyn_mw


# Table III (latency, ns) + Table V (power, mW) - HP runs at 1.2 V.
HP_MRAM = MemorySpec("mram", read_ns=2.62, write_ns=11.81,
                     read_mw=428.48, write_mw=133.78, static_mw=2.98,
                     volatile=False)
HP_SRAM = MemorySpec("sram", read_ns=1.12, write_ns=1.12,
                     read_mw=508.93, write_mw=500.0, static_mw=23.29,
                     volatile=True)
HP_PE = PESpec(op_ns=5.52, dyn_mw=0.9, static_mw=0.48)

# LP runs at 0.8 V.
LP_MRAM = MemorySpec("mram", read_ns=2.96, write_ns=14.65,
                     read_mw=179.05, write_mw=47.78, static_mw=0.84,
                     volatile=False)
LP_SRAM = MemorySpec("sram", read_ns=1.41, write_ns=1.41,
                     read_mw=177.3, write_mw=177.3, static_mw=5.45,
                     volatile=True)
LP_PE = PESpec(op_ns=10.68, dyn_mw=0.51, static_mw=0.25)


# ---------------------------------------------------------------------------
# Storage spaces (the knapsack "items") and clusters
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StorageSpace:
    """One of the four placement targets (e.g. HP-MRAM).

    ``io`` is the SRAM bank used as the input/output buffer of the owning
    cluster: every MAC fetches one input operand from it (paper SS.II - SRAM
    retains the I/O-buffer role of H-PIM designs).
    """

    name: str            # "hp_mram" | "hp_sram" | "lp_mram" | "lp_sram"
    cluster: str         # "hp" | "lp"
    mem: MemorySpec
    io: MemorySpec
    pe: PESpec
    n_modules: int       # banks of this type == modules in the cluster
    banks_per_module: int = 1

    # -- per-MAC characteristics (a weight-reuse factor rho >= 1 amortizes the
    #    weight fetch over rho MACs; the paper's PE is weight-per-op, rho=1).
    def op_ns(self, rho: float = 1.0) -> float:
        return self.io.read_ns + self.mem.read_ns / rho + self.pe.op_ns

    def op_pj(self, rho: float = 1.0) -> float:
        return (self.io.read_pj + self.mem.read_pj / rho + self.pe.op_pj)

    @property
    def capacity_weights(self) -> int:
        """INT8 weights storable cluster-wide in this space."""
        return self.mem.capacity_bytes * self.banks_per_module * self.n_modules

    @property
    def static_mw_total(self) -> float:
        return self.mem.static_mw * self.banks_per_module * self.n_modules


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    name: str
    pe: PESpec
    n_modules: int
    spaces: Tuple[StorageSpace, ...]   # (mram?, sram) present in each module

    @property
    def pe_static_mw_total(self) -> float:
        return self.pe.static_mw * self.n_modules

    def space(self, kind: str) -> StorageSpace:
        for s in self.spaces:
            if s.mem.kind == kind:
                return s
        raise KeyError(f"cluster {self.name} has no {kind}")


@dataclasses.dataclass(frozen=True)
class PIMArch:
    """A full PIM processor configuration (Table I row)."""

    name: str
    clusters: Tuple[ClusterSpec, ...]

    @property
    def spaces(self) -> List[StorageSpace]:
        out: List[StorageSpace] = []
        for c in self.clusters:
            out.extend(c.spaces)
        return out

    def cluster(self, name: str) -> ClusterSpec:
        for c in self.clusters:
            if c.name == name:
                return c
        raise KeyError(name)


def _mk_cluster(name: str, mram: MemorySpec | None, sram: MemorySpec,
                pe: PESpec, n_modules: int,
                sram_banks: int = 1) -> ClusterSpec:
    spaces = []
    if mram is not None:
        spaces.append(StorageSpace(f"{name}_mram", name, mram, sram, pe,
                                   n_modules))
    spaces.append(StorageSpace(f"{name}_sram", name, sram, sram, pe,
                               n_modules, banks_per_module=sram_banks))
    return ClusterSpec(name, pe, n_modules, tuple(spaces))


def hh_pim(n_hp: int = 4, n_lp: int = 4) -> PIMArch:
    """HH-PIM: 4 HP + 4 LP modules, 64 kB MRAM + 64 kB SRAM each (Table I)."""
    return PIMArch("hh_pim", (
        _mk_cluster("hp", HP_MRAM, HP_SRAM, HP_PE, n_hp),
        _mk_cluster("lp", LP_MRAM, LP_SRAM, LP_PE, n_lp),
    ))


def baseline_pim(n_modules: int = 8) -> PIMArch:
    """Baseline-PIM: 8 HP modules, 128 kB SRAM (two 64 kB banks) each."""
    return PIMArch("baseline_pim", (
        _mk_cluster("hp", None, HP_SRAM, HP_PE, n_modules, sram_banks=2),
    ))


def hetero_pim(n_hp: int = 4, n_lp: int = 4) -> PIMArch:
    """Heterogeneous-PIM: 4 HP + 4 LP modules, 128 kB SRAM each."""
    return PIMArch("hetero_pim", (
        _mk_cluster("hp", None, HP_SRAM, HP_PE, n_hp, sram_banks=2),
        _mk_cluster("lp", None, LP_SRAM, LP_PE, n_lp, sram_banks=2),
    ))


def hybrid_pim(n_modules: int = 8) -> PIMArch:
    """Hybrid-PIM (H-PIM): 8 HP modules, 64 kB MRAM + 64 kB SRAM each.

    Weights live in MRAM; SRAM is the I/O buffer (conventional H-PIM policy).
    """
    return PIMArch("hybrid_pim", (
        _mk_cluster("hp", HP_MRAM, HP_SRAM, HP_PE, n_modules),
    ))


# ---------------------------------------------------------------------------
# Benchmark workloads (Table IV)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A TinyML benchmark model (Table IV). INT8-quantized and pruned."""

    name: str
    n_params: int        # weight count (= INT8 bytes)
    n_macs: int
    pim_ratio: float     # fraction of MACs executed on the PIM

    @property
    def pim_ops(self) -> int:
        """MACs executed by the PIM fabric per inference (one *task*)."""
        return int(round(self.n_macs * self.pim_ratio))

    @property
    def ops_per_weight(self) -> float:
        return self.pim_ops / self.n_params


EFFICIENTNET_B0 = ModelSpec("efficientnet_b0", 95_000, 3_245_000, 0.85)
MOBILENET_V2 = ModelSpec("mobilenet_v2", 101_000, 2_528_000, 0.80)
RESNET_18 = ModelSpec("resnet_18", 256_000, 29_580_000, 0.75)

TINYML_MODELS: Dict[str, ModelSpec] = {
    m.name: m for m in (EFFICIENTNET_B0, MOBILENET_V2, RESNET_18)
}
