"""``repro.core.substrate`` - one parametric interface over placement
substrates.

DESIGN.md SS.3 proves Eq. (1) of the paper is substrate-agnostic:
Algorithms 1/2 only need per-space ``(t_i, e_i)``. A :class:`Substrate`
bundles everything an entry point needs to instantiate the stack for one
hardware platform - the :class:`~repro.core.spaces.PIMArch`, a
``model_spec(workload)`` mapping, the energy model, the LUT builder
(through the pluggable :mod:`repro.core.solvers`), and
``apply_placement`` (functional weight migration, where the platform has
one) - behind a string-keyed registry:

  ================== ==================================================
  ``edge-hhpim``     HH-PIM (Table I row 4), dynamic closed-form solver
  ``edge-hetero``    Heterogeneous-PIM, fixed balanced-SRAM policy
  ``edge-hybrid``    Hybrid-PIM, fixed MRAM-resident policy
  ``edge-baseline``  Baseline-PIM, fixed all-SRAM policy
  ``tpu-pool``       HP/LP TPU chip pools x {bf16, int8} residency
  ``tpu-pool-mixed`` same, heterogeneous fleet shapes (odd engines half)
  ================== ==================================================

Adding a backend is one :func:`register_substrate` call (DESIGN.md SS.5);
use :mod:`repro.api` to construct schedulers/engines/fleets from a name.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple, Union

from repro.core import spaces as sp
from repro.core import workloads
from repro.core.energy import EnergyModel, Placement
from repro.core.placement import PlacementLUT
from repro.core.solvers import make_solver


class Substrate:
    """Protocol: everything Eq. (1) needs from one hardware platform."""

    name: str
    arch: sp.PIMArch
    rho: float
    solver: str                      # default solver registry key
    lut_points: int
    # True when the substrate can drive a functional serve engine
    # (api.engine / api.fleet(decode=True)); accounting-only otherwise
    supports_decode = False

    # -- workload mapping --------------------------------------------------
    def model_spec(self, workload=None, **hint) -> sp.ModelSpec:
        """Resolve a workload handle (name / ModelSpec / ModelConfig) to
        the substrate's :class:`~repro.core.spaces.ModelSpec`. Extra
        keywords are substrate-specific hints (e.g. ``tokens_per_task``)."""
        raise NotImplementedError

    # -- modeling ----------------------------------------------------------
    def energy_model(self, workload=None, *, rho: Optional[float] = None,
                     time_scale=None) -> EnergyModel:
        return EnergyModel(self.arch, self.model_spec(workload),
                           rho=self.rho if rho is None else rho,
                           time_scale=time_scale)

    def default_t_slice_ns(self, workload=None, *,
                           rho: Optional[float] = None) -> float:
        raise NotImplementedError

    def build_lut(self, workload=None, *, solver=None,
                  t_slice_ns: Optional[float] = None,
                  n_points: Optional[int] = None,
                  rho: Optional[float] = None) -> PlacementLUT:
        em = self.energy_model(workload, rho=rho)
        if t_slice_ns is None:
            t_slice_ns = self.default_t_slice_ns(em.model, rho=rho)
        return make_solver(solver or self.solver).build_lut(
            em, t_slice_ns=t_slice_ns,
            n_points=self.lut_points if n_points is None else n_points)

    # -- functional placement ----------------------------------------------
    def apply_placement(self, placement: Placement, sink=None) -> bool:
        """Apply ``placement`` to the functional weight store ``sink``
        (e.g. a serve engine). Accounting-only substrates return False -
        placement lives purely in the energy/timing model."""
        return False

    # -- fleet shaping -----------------------------------------------------
    def engine_variant(self, index: int) -> "Substrate":
        """Substrate for fleet engine ``index`` (homogeneous: self)."""
        return self

    def variant_key(self) -> tuple:
        """Hashable shape key; engines sharing it share one LUT."""
        return (self.name,)

    def replace(self, **kw) -> "Substrate":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class EdgeSubstrate(Substrate):
    """The paper's edge-PIM platforms (Tables I/III/V constants).

    ``reference_arch`` sizes the default time slice: the paper's
    comparison protocol gives every arch the slice that fits
    ``workloads.PEAK_TASKS`` inferences at *HH-PIM* peak performance, so
    savings are measured under identical deadlines.
    """

    name: str
    arch: sp.PIMArch
    rho: float = 1.0
    solver: str = "closed-form"
    lut_points: int = 64
    reference_arch: Optional[sp.PIMArch] = None

    def model_spec(self, workload=None, **hint) -> sp.ModelSpec:
        if workload is None:
            return sp.EFFICIENTNET_B0
        if isinstance(workload, sp.ModelSpec):
            return workload
        if isinstance(workload, str):
            try:
                return sp.TINYML_MODELS[workload]
            except KeyError:
                raise ValueError(
                    f"unknown TinyML workload {workload!r}; one of "
                    f"{sorted(sp.TINYML_MODELS)}") from None
        raise TypeError(f"cannot interpret workload {workload!r} for "
                        f"substrate {self.name}")

    def default_t_slice_ns(self, workload=None, *,
                           rho: Optional[float] = None,
                           headroom: float = 1.01) -> float:
        model = self.model_spec(workload)
        em = EnergyModel(self.reference_arch or self.arch, model,
                         rho=self.rho if rho is None else rho)
        t_peak = em.task_cost(em.peak_placement(sram_only=True)).t_task_ns
        return t_peak * workloads.PEAK_TASKS * headroom


@dataclasses.dataclass(frozen=True)
class TPUPoolSubstrate(Substrate):
    """HP/LP TPU chip pools with {bf16, int8} weight residency as the
    storage spaces (DESIGN.md SS.3). ``mixed=True`` makes
    :meth:`engine_variant` give odd-indexed fleet engines half the chips
    (the heterogeneous-pool serving scenario)."""

    supports_decode = True

    name: str = "tpu-pool"
    n_hp_chips: int = 4
    n_lp_chips: int = 4
    tokens_per_task: int = 8
    rho: float = 64.0
    solver: str = "closed-form"
    lut_points: int = 32
    peak_tasks: int = workloads.PEAK_TASKS
    mixed: bool = False
    arch: sp.PIMArch = dataclasses.field(init=False, compare=False)

    def __post_init__(self):
        from repro.serve.hetero import tpu_arch
        object.__setattr__(self, "arch",
                           tpu_arch(self.n_hp_chips, self.n_lp_chips))

    def model_spec(self, workload=None, **hint) -> sp.ModelSpec:
        if isinstance(workload, sp.ModelSpec):
            return workload
        from repro.serve.hetero import tpu_model_spec
        if workload is None:
            from repro.configs import get_smoke_config
            workload = get_smoke_config("internlm2_1_8b")
        tokens = hint.get("tokens_per_task") or self.tokens_per_task
        return tpu_model_spec(workload, tokens)

    def default_t_slice_ns(self, workload=None, *,
                           rho: Optional[float] = None) -> float:
        from repro.serve.hetero import default_t_slice_ms
        return default_t_slice_ms(
            self.arch, self.model_spec(workload),
            rho=self.rho if rho is None else rho,
            peak_tasks=self.peak_tasks) * 1e6

    def apply_placement(self, placement: Placement, sink=None) -> bool:
        """Re-tier the sink engine's weights (real re-quantization and
        column splits); accounting-only when no sink is attached."""
        if sink is None:
            return False
        return sink.apply_placement(placement)

    def chip_plan(self, index: int) -> Tuple[int, int]:
        if self.mixed and index % 2 == 1:
            return (max(self.n_hp_chips // 2, 1),
                    max(self.n_lp_chips // 2, 1))
        return (self.n_hp_chips, self.n_lp_chips)

    def engine_variant(self, index: int) -> "TPUPoolSubstrate":
        hp, lp = self.chip_plan(index)
        if (hp, lp) == (self.n_hp_chips, self.n_lp_chips):
            return self
        return dataclasses.replace(self, n_hp_chips=hp, n_lp_chips=lp,
                                   mixed=False)

    def variant_key(self) -> tuple:
        return (self.name, self.n_hp_chips, self.n_lp_chips)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

SubstrateFactory = Callable[..., Substrate]
SUBSTRATES: Dict[str, SubstrateFactory] = {}


def register_substrate(name: str, factory: SubstrateFactory) -> None:
    SUBSTRATES[name] = factory


def make_substrate(name: Union[str, Substrate], **over) -> Substrate:
    """Build a substrate by registry name; keyword overrides go to the
    factory (e.g. ``rho=``, ``n_hp_chips=``). Instances pass through
    (overrides applied via ``dataclasses.replace``)."""
    if isinstance(name, Substrate):
        return name.replace(**over) if over else name
    if name not in SUBSTRATES:
        raise ValueError(
            f"unknown substrate {name!r}; one of {sorted(SUBSTRATES)}")
    return SUBSTRATES[name](**over)


def available_substrates() -> Tuple[str, ...]:
    return tuple(sorted(SUBSTRATES))


def _edge_factory(name: str, arch_builder: Callable[..., sp.PIMArch],
                  solver: str) -> SubstrateFactory:
    def factory(*, rho: float = 1.0, solver: str = solver,
                lut_points: int = 64, **arch_kw) -> EdgeSubstrate:
        return EdgeSubstrate(name=name, arch=arch_builder(**arch_kw),
                             rho=rho, solver=solver, lut_points=lut_points,
                             reference_arch=sp.hh_pim())
    return factory


def _tpu_factory(name: str, mixed: bool) -> SubstrateFactory:
    def factory(**kw) -> TPUPoolSubstrate:
        return TPUPoolSubstrate(name=name, mixed=mixed, **kw)
    return factory


register_substrate("edge-hhpim",
                   _edge_factory("edge-hhpim", sp.hh_pim, "closed-form"))
register_substrate("edge-hetero",
                   _edge_factory("edge-hetero", sp.hetero_pim,
                                 "fixed-hetero"))
register_substrate("edge-hybrid",
                   _edge_factory("edge-hybrid", sp.hybrid_pim,
                                 "fixed-hybrid"))
register_substrate("edge-baseline",
                   _edge_factory("edge-baseline", sp.baseline_pim,
                                 "fixed-baseline"))
register_substrate("tpu-pool", _tpu_factory("tpu-pool", mixed=False))
register_substrate("tpu-pool-mixed",
                   _tpu_factory("tpu-pool-mixed", mixed=True))
