"""``repro.core.substrate`` - one parametric interface over placement
substrates.

DESIGN.md SS.3 proves Eq. (1) of the paper is substrate-agnostic:
Algorithms 1/2 only need per-space ``(t_i, e_i)``. A :class:`Substrate`
bundles everything an entry point needs to instantiate the stack for one
hardware platform - the :class:`~repro.core.spaces.PIMArch`, a
``model_spec(workload)`` mapping, the energy model, the LUT builder
(through the pluggable :mod:`repro.core.solvers`), and
``apply_placement`` (functional weight migration, where the platform has
one) - behind a string-keyed registry:

  ================== ==================================================
  ``edge-hhpim``     HH-PIM (Table I row 4), dynamic closed-form solver
  ``edge-hetero``    Heterogeneous-PIM, fixed balanced-SRAM policy
  ``edge-hybrid``    Hybrid-PIM, fixed MRAM-resident policy
  ``edge-baseline``  Baseline-PIM, fixed all-SRAM policy
  ``tpu-pool``       HP/LP TPU chip pools x {bf16, int8} residency
  ``tpu-pool-mixed`` same, heterogeneous fleet shapes (odd engines half)
  ``gpu-pool``       HP/LP GPU SM-cluster pools at two DVFS points x
                     {bf16, fp8/int8} HBM residency (``lp_clock`` knob)
  ``gpu-pool-mixed`` same, heterogeneous fleet shapes (odd engines half)
  ``cxl-tier``       HP/LP node pools x {node-local DDR, CXL-attached}
                     residency (edge-to-cloud memory tiering)
  ``cxl-tier-3``     THREE pools - HBM / node-DDR / CXL-attached far
                     (DVFS-scaled) - solved through the K-pool
                     min-plus combine (repro.core.multipool)
  ``cxl-tier-3-mixed`` same, heterogeneous fleet shapes (odd engines
                     get half of all THREE pools, floored at 1)
  ================== ==================================================

Adding a backend is one :func:`register_substrate` call (DESIGN.md SS.5);
use :mod:`repro.api` to construct schedulers/engines/fleets from a name.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple, Union

from repro.core import spaces as sp
from repro.core import workloads
from repro.core.energy import EnergyModel, Placement
from repro.core.placement import PlacementLUT
from repro.core.solvers import make_solver


class Substrate:
    """Protocol: everything Eq. (1) needs from one hardware platform."""

    name: str
    arch: sp.PIMArch
    rho: float
    solver: str                      # default solver registry key
    lut_points: int
    # True when the substrate can drive a functional serve engine
    # (api.engine / api.fleet(decode=True)); accounting-only otherwise
    supports_decode = False
    # window the LUT charges volatile-residency static energy over:
    # "t_constraint" (paper's per-task accounting) or "t_slice" (serving
    # pools with a pinned slice length - see GPUPoolSubstrate)
    static_window = "t_constraint"
    # registered TechModel name (repro.core.techmodel) where the
    # substrate has a DVFS axis; None = fixed-voltage platform (the
    # edge archs' HP/LP split is baked into Table I constants)
    tech: Optional[str] = None

    # -- technology / DVFS axis (DESIGN.md SS.10) --------------------------
    def tech_model(self):
        """The registered :class:`~repro.core.techmodel.TechModel`
        behind this substrate's DVFS axis, or None on fixed-voltage
        platforms."""
        if self.tech is None:
            return None
        from repro.core.techmodel import get_tech_model
        return get_tech_model(self.tech)

    def with_clock(self, clock: float) -> "Substrate":
        """This substrate re-pointed to DVFS scale ``clock`` (clamped
        into the TechModel's operating bounds). The clocked variant has
        a distinct ``variant_key()``, so grid points never collide in a
        shared compiler cache."""
        tm = self.tech_model()
        if tm is None or not hasattr(self, "lp_clock"):
            raise ValueError(
                f"substrate {self.name!r} has no DVFS axis (tech="
                f"{self.tech!r}); register a TechModel and an lp_clock "
                f"field to make the clock a solved variable")
        return dataclasses.replace(self, lp_clock=tm.clamp(clock))

    # -- workload mapping --------------------------------------------------
    def model_spec(self, workload=None, **hint) -> sp.ModelSpec:
        """Resolve a workload handle (name / ModelSpec / ModelConfig) to
        the substrate's :class:`~repro.core.spaces.ModelSpec`. Extra
        keywords are substrate-specific hints (e.g. ``tokens_per_task``)."""
        raise NotImplementedError

    # -- modeling ----------------------------------------------------------
    def energy_model(self, workload=None, *, rho: Optional[float] = None,
                     time_scale=None) -> EnergyModel:
        return EnergyModel(self.arch, self.model_spec(workload),
                           rho=self.rho if rho is None else rho,
                           time_scale=time_scale)

    def default_t_slice_ns(self, workload=None, *,
                           rho: Optional[float] = None) -> float:
        raise NotImplementedError

    def build_lut(self, workload=None, *, solver=None,
                  t_slice_ns: Optional[float] = None,
                  n_points: Optional[int] = None,
                  rho: Optional[float] = None,
                  compiler=None) -> PlacementLUT:
        """Build the placement LUT through the (or the named) solver; a
        :class:`~repro.core.compiler.PlacementCompiler` routes the build
        through its shared cache instead."""
        em = self.energy_model(workload, rho=rho)
        if t_slice_ns is None:
            t_slice_ns = self.default_t_slice_ns(em.model, rho=rho)
        n = self.lut_points if n_points is None else n_points
        if compiler is not None:
            return compiler.lut(em, solver=solver or self.solver,
                                t_slice_ns=t_slice_ns, n_points=n,
                                static_window=self.static_window,
                                variant_key=self.variant_key())
        return make_solver(solver or self.solver).build_lut(
            em, t_slice_ns=t_slice_ns, n_points=n,
            static_window=self.static_window)

    # -- functional placement ----------------------------------------------
    def apply_placement(self, placement: Placement, sink=None) -> bool:
        """Apply ``placement`` to the functional weight store ``sink``
        (e.g. a serve engine). Accounting-only substrates return False -
        placement lives purely in the energy/timing model."""
        return False

    # -- fleet shaping -----------------------------------------------------
    def engine_variant(self, index: int) -> "Substrate":
        """Substrate for fleet engine ``index`` (homogeneous: self)."""
        return self

    def variant_key(self) -> tuple:
        """Hashable shape key; engines sharing it share one LUT and one
        :class:`~repro.core.compiler.PlacementCompiler` cache entry. The
        default fingerprints the arch's space shaping, so substrates of
        the same name built with different arch kwargs (module/bank
        counts) never collide in a shared compiler cache."""
        return (self.name,) + tuple(
            (s.name, s.n_modules, s.banks_per_module)
            for s in self.arch.spaces)

    def replace(self, **kw) -> "Substrate":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class EdgeSubstrate(Substrate):
    """The paper's edge-PIM platforms (Tables I/III/V constants).

    ``reference_arch`` sizes the default time slice: the paper's
    comparison protocol gives every arch the slice that fits
    ``workloads.PEAK_TASKS`` inferences at *HH-PIM* peak performance, so
    savings are measured under identical deadlines.
    """

    name: str
    arch: sp.PIMArch
    rho: float = 1.0
    solver: str = "closed-form"
    lut_points: int = 64
    reference_arch: Optional[sp.PIMArch] = None

    def model_spec(self, workload=None, **hint) -> sp.ModelSpec:
        if workload is None:
            return sp.EFFICIENTNET_B0
        if isinstance(workload, sp.ModelSpec):
            return workload
        if isinstance(workload, str):
            try:
                return sp.TINYML_MODELS[workload]
            except KeyError:
                raise ValueError(
                    f"unknown TinyML workload {workload!r}; one of "
                    f"{sorted(sp.TINYML_MODELS)}") from None
        raise TypeError(f"cannot interpret workload {workload!r} for "
                        f"substrate {self.name}")

    def default_t_slice_ns(self, workload=None, *,
                           rho: Optional[float] = None,
                           headroom: float = 1.01) -> float:
        model = self.model_spec(workload)
        em = EnergyModel(self.reference_arch or self.arch, model,
                         rho=self.rho if rho is None else rho)
        t_peak = em.task_cost(em.peak_placement(sram_only=True)).t_task_ns
        return t_peak * workloads.PEAK_TASKS * headroom


class ServePoolSubstrate(Substrate):
    """Shared protocol of the serving pool substrates (``tpu-pool``,
    ``gpu-pool``): an HP and an LP compute pool with per-precision HBM
    weight residency as the storage spaces, decoded through a functional
    ``HeteroServeEngine`` (DESIGN.md SS.3/SS.5). Subclasses supply the
    pool fields, the arch builder and the mixed-fleet shaping; workload
    mapping (serving ModelConfig -> task spec), slice sizing, mixed-fleet
    shaping (via ``_POOL_FIELDS``) and functional placement application
    are identical across pools."""

    supports_decode = True
    #: names of the dataclass fields holding the pool sizes (chips / SM
    #: clusters / nodes), one per cluster; the shared fleet-shaping
    #: methods below operate on whatever - and however many - fields
    #: the subclass declares (2 for the HP/LP pools, 3 for the
    #: three-tier ``cxl-tier-3``).
    _POOL_FIELDS = ("n_hp", "n_lp")

    def _pool_counts(self) -> Tuple[int, ...]:
        return tuple(getattr(self, f) for f in self._POOL_FIELDS)

    def pool_plan(self, index: int) -> Tuple[int, ...]:
        """Per-cluster pool sizes of fleet engine ``index``:
        ``mixed=True`` gives odd-indexed engines half of each pool
        (floored at 1)."""
        counts = self._pool_counts()
        if self.mixed and index % 2 == 1:
            return tuple(max(c // 2, 1) for c in counts)
        return counts

    def engine_variant(self, index: int) -> "ServePoolSubstrate":
        counts = self.pool_plan(index)
        if counts == self._pool_counts():
            return self
        return dataclasses.replace(self, mixed=False,
                                   **dict(zip(self._POOL_FIELDS, counts)))

    def variant_key(self) -> tuple:
        """(name, *pool sizes[, lp_clock]) - pool sizes fully determine
        the arch, plus the DVFS point where the pool has one (engines
        at different DVFS points must not share a LUT)."""
        key = (self.name,) + self._pool_counts()
        lp_clock = getattr(self, "lp_clock", None)
        if lp_clock is not None:
            key += (round(lp_clock, 4),)
        return key

    def tier_plan(self) -> Tuple[Tuple[str, str, str], ...]:
        """Ordered ``(space_name, tier_name, format)`` triples driving
        the serve engine's functional column split
        (:mod:`repro.models.hetero_linear`). Default mapping: volatile
        residency decodes in bf16, non-volatile residency in int8 (the
        tpu/gpu pool convention - the legacy hp_bf16/.../lp_int8
        order). CXL substrates override with int8/int8 tier pairs."""
        plan = []
        for c in self.arch.clusters:
            for kind, fmt in (("sram", "bf16"), ("mram", "int8")):
                for s in c.spaces:
                    if s.mem.kind == kind:
                        plan.append((s.name, f"{c.name}_{fmt}", fmt))
        return tuple(plan)

    def model_spec(self, workload=None, **hint) -> sp.ModelSpec:
        if isinstance(workload, sp.ModelSpec):
            return workload
        from repro.serve.hetero import tpu_model_spec
        if workload is None:
            from repro.configs import get_smoke_config
            workload = get_smoke_config("internlm2_1_8b")
        tokens = hint.get("tokens_per_task") or self.tokens_per_task
        return tpu_model_spec(workload, tokens)

    def default_t_slice_ns(self, workload=None, *,
                           rho: Optional[float] = None) -> float:
        from repro.serve.hetero import default_t_slice_ms
        return default_t_slice_ms(
            self.arch, self.model_spec(workload),
            rho=self.rho if rho is None else rho,
            peak_tasks=self.peak_tasks) * 1e6

    def apply_placement(self, placement: Placement, sink=None) -> bool:
        """Re-tier the sink engine's weights (real re-quantization and
        column splits); accounting-only when no sink is attached."""
        if sink is None:
            return False
        return sink.apply_placement(placement)


@dataclasses.dataclass(frozen=True)
class TPUPoolSubstrate(ServePoolSubstrate):
    """HP/LP TPU chip pools with {bf16, int8} weight residency as the
    storage spaces (DESIGN.md SS.3). ``mixed=True`` makes
    :meth:`engine_variant` give odd-indexed fleet engines half the chips
    (the heterogeneous-pool serving scenario)."""

    name: str = "tpu-pool"
    n_hp_chips: int = 4
    n_lp_chips: int = 4
    tokens_per_task: int = 8
    rho: float = 64.0
    solver: str = "closed-form"
    lut_points: int = 32
    peak_tasks: int = workloads.PEAK_TASKS
    mixed: bool = False
    arch: sp.PIMArch = dataclasses.field(init=False, compare=False)

    _POOL_FIELDS = ("n_hp_chips", "n_lp_chips")

    def __post_init__(self):
        from repro.serve.hetero import tpu_arch
        object.__setattr__(self, "arch",
                           tpu_arch(self.n_hp_chips, self.n_lp_chips))


@dataclasses.dataclass(frozen=True)
class GPUPoolSubstrate(ServePoolSubstrate):
    """HP/LP GPU SM-cluster pools at two DVFS operating points with
    {bf16, fp8/int8} HBM residency as the storage spaces (DESIGN.md SS.5,
    constants in :mod:`repro.serve.gpu`).

    ``lp_clock`` is the DVFS sweep knob: the LP pool's frequency scale in
    (0, 1]. Lowering it stretches LP per-op latency as ``1/lp_clock`` and
    shrinks LP dynamic/static energy as ``dvfs_energy_scale(lp_clock)``,
    so sweeping it traces the energy-vs-latency frontier on this backend
    (``examples/placement_sweep.py``). ``mixed=True`` gives odd-indexed
    fleet engines half the SM clusters of each pool.

    The LUT charges volatile (bf16) residency statics over the full slice
    (``static_window="t_slice"``): a serving pool runs a pinned slice
    length, so a pool holding bf16 shards stays at its operating point for
    all of ``T`` regardless of the per-task constraint. This also keeps
    the LUT's ranking consistent with realized slice energy, which the
    dp/closed-form agreement check relies on."""

    static_window = "t_slice"
    tech = "sm-pool-7nm"         # repro.serve.gpu.TECH

    name: str = "gpu-pool"
    n_hp_clusters: int = 8
    n_lp_clusters: int = 8
    lp_clock: float = 0.45          # repro.serve.gpu.LP_CLOCK
    tokens_per_task: int = 8
    rho: float = 64.0
    solver: str = "closed-form"
    lut_points: int = 32
    peak_tasks: int = workloads.PEAK_TASKS
    mixed: bool = False
    arch: sp.PIMArch = dataclasses.field(init=False, compare=False)

    _POOL_FIELDS = ("n_hp_clusters", "n_lp_clusters")

    def __post_init__(self):
        from repro.serve.gpu import gpu_arch
        object.__setattr__(self, "arch",
                           gpu_arch(self.n_hp_clusters, self.n_lp_clusters,
                                    lp_clock=self.lp_clock))


@dataclasses.dataclass(frozen=True)
class CXLTierSubstrate(ServePoolSubstrate):
    """HP/LP node pools with {node-local DDR, CXL-attached} residency as
    the volatile/non-volatile storage-space pair (constants in
    :mod:`repro.serve.cxl`; after Oliveira et al., PAPERS.md).

    The edge-to-cloud tiering scenario: weights are INT8 in both tiers,
    so the placement trade is pure locality (local DDR bandwidth, but
    refresh + PHY stay up while holding) versus standby power (the CXL
    expander powers down in retention when its pool idles, but every
    read pays the link premium). ``lp_clock`` scales the efficiency
    pool's node clock exactly as on the GPU pools. Decode-capable:
    weights are INT8 in both tiers, so :meth:`tier_plan` maps every
    space to an int8/int8 tier pair and a placement change re-tiers
    real weight columns through ``HeteroServeEngine`` just like the
    TPU/GPU pools (what moves is the column split, not the format)."""

    static_window = "t_slice"    # pinned-slice pools: see GPUPoolSubstrate
    tech = "cxl-node-10nm"       # repro.serve.cxl.TECH

    name: str = "cxl-tier"
    n_hp_nodes: int = 4
    n_lp_nodes: int = 4
    lp_clock: float = 0.5        # repro.serve.cxl.LP_CLOCK
    tokens_per_task: int = 8
    rho: float = 32.0
    solver: str = "closed-form"
    lut_points: int = 32
    peak_tasks: int = workloads.PEAK_TASKS
    mixed: bool = False
    arch: sp.PIMArch = dataclasses.field(init=False, compare=False)

    _POOL_FIELDS = ("n_hp_nodes", "n_lp_nodes")

    def __post_init__(self):
        from repro.serve.cxl import cxl_arch
        object.__setattr__(self, "arch",
                           cxl_arch(self.n_hp_nodes, self.n_lp_nodes,
                                    lp_clock=self.lp_clock))

    def tier_plan(self) -> Tuple[Tuple[str, str, str], ...]:
        """INT8 in both residency tiers: DDR-local ("sram") and CXL-far
        ("mram") spaces both decode through the W8A8 kernel, so a
        placement change is a pure column move between int8 segments."""
        tier = {"sram": "ddr", "mram": "cxl"}
        plan = []
        for c in self.arch.clusters:
            for kind in ("sram", "mram"):
                for s in c.spaces:
                    if s.mem.kind == kind:
                        plan.append((s.name,
                                     f"{c.name}_{tier[kind]}_int8", "int8"))
        return tuple(plan)


@dataclasses.dataclass(frozen=True)
class CXLTier3Substrate(ServePoolSubstrate):
    """Three-tier memory hierarchy as three compute pools - HBM
    accelerator nodes / node-DDR standard nodes / a DVFS-scaled far
    pool behind the CXL link (``repro.serve.cxl.cxl_arch3``; after
    Oliveira et al., PAPERS.md).

    The first 3-cluster substrate: the LUT builders solve it through
    the K-pool min-plus combine (:mod:`repro.core.multipool`,
    DESIGN.md SS.7) on both the closed-form and the kernel-backed DP
    path. Each pool anchors one residency tier, so the placement
    decision is a genuine three-way split over the hierarchy: HBM
    (fast, highest standby while holding), node DDR (mid), CXL far
    memory (link premium per read, retention power-down when idle,
    DVFS-scaled compute via ``lp_clock``). Decode-capable like
    ``cxl-tier``: all three tiers are int8 segments, so placement
    changes re-tier real weight columns."""

    static_window = "t_slice"    # pinned-slice pools: see GPUPoolSubstrate
    tech = "cxl-node-10nm"       # far pool rides the CXL node curve

    name: str = "cxl-tier-3"
    n_hbm_nodes: int = 2
    n_ddr_nodes: int = 4
    n_cxl_nodes: int = 4
    lp_clock: float = 0.5        # far-pool DVFS scale
    tokens_per_task: int = 8
    rho: float = 32.0
    solver: str = "closed-form"
    lut_points: int = 32
    peak_tasks: int = workloads.PEAK_TASKS
    mixed: bool = False
    arch: sp.PIMArch = dataclasses.field(init=False, compare=False)

    _POOL_FIELDS = ("n_hbm_nodes", "n_ddr_nodes", "n_cxl_nodes")

    def __post_init__(self):
        from repro.serve.cxl import cxl_arch3
        object.__setattr__(self, "arch",
                           cxl_arch3(self.n_hbm_nodes, self.n_ddr_nodes,
                                     self.n_cxl_nodes,
                                     lp_clock=self.lp_clock))

    def tier_plan(self) -> Tuple[Tuple[str, str, str], ...]:
        """One int8 tier per pool (hbm/ddr/cxl): a 3-way column split."""
        return tuple((c.spaces[0].name, f"{c.name}_int8", "int8")
                     for c in self.arch.clusters)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

SubstrateFactory = Callable[..., Substrate]
SUBSTRATES: Dict[str, SubstrateFactory] = {}


def register_substrate(name: str, factory: SubstrateFactory) -> None:
    SUBSTRATES[name] = factory


def make_substrate(name: Union[str, Substrate], **over) -> Substrate:
    """Build a substrate by registry name; keyword overrides go to the
    factory (e.g. ``rho=``, ``n_hp_chips=``). Instances pass through
    (overrides applied via ``dataclasses.replace``)."""
    if isinstance(name, Substrate):
        return name.replace(**over) if over else name
    if name not in SUBSTRATES:
        raise ValueError(
            f"unknown substrate {name!r}; one of {sorted(SUBSTRATES)}")
    return SUBSTRATES[name](**over)


def available_substrates() -> Tuple[str, ...]:
    return tuple(sorted(SUBSTRATES))


def list_substrates() -> Tuple[str, ...]:
    """Every registered substrate name, sorted. The CI substrate-smoke
    job iterates this and runs LUT build + one scheduler slice per entry,
    so a broken registry entry fails CI."""
    return available_substrates()


def _edge_factory(name: str, arch_builder: Callable[..., sp.PIMArch],
                  solver: str) -> SubstrateFactory:
    def factory(*, rho: float = 1.0, solver: str = solver,
                lut_points: int = 64, **arch_kw) -> EdgeSubstrate:
        return EdgeSubstrate(name=name, arch=arch_builder(**arch_kw),
                             rho=rho, solver=solver, lut_points=lut_points,
                             reference_arch=sp.hh_pim())
    return factory


def _tpu_factory(name: str, mixed: bool) -> SubstrateFactory:
    def factory(**kw) -> TPUPoolSubstrate:
        return TPUPoolSubstrate(name=name, mixed=mixed, **kw)
    return factory


register_substrate("edge-hhpim",
                   _edge_factory("edge-hhpim", sp.hh_pim, "closed-form"))
register_substrate("edge-hetero",
                   _edge_factory("edge-hetero", sp.hetero_pim,
                                 "fixed-hetero"))
register_substrate("edge-hybrid",
                   _edge_factory("edge-hybrid", sp.hybrid_pim,
                                 "fixed-hybrid"))
register_substrate("edge-baseline",
                   _edge_factory("edge-baseline", sp.baseline_pim,
                                 "fixed-baseline"))
def _gpu_factory(name: str, mixed: bool) -> SubstrateFactory:
    def factory(**kw) -> GPUPoolSubstrate:
        return GPUPoolSubstrate(name=name, mixed=mixed, **kw)
    return factory


def _cxl_factory(**kw) -> CXLTierSubstrate:
    return CXLTierSubstrate(**kw)


def _cxl3_factory(**kw) -> CXLTier3Substrate:
    return CXLTier3Substrate(**kw)


def _cxl3_mixed_factory(**kw) -> CXLTier3Substrate:
    # the generalized _POOL_FIELDS machinery halves all three pools for
    # odd-indexed engines (floored at 1); variant_key() keeps half- and
    # full-shape engines on separate LUT cache entries
    return CXLTier3Substrate(name="cxl-tier-3-mixed", mixed=True, **kw)


register_substrate("tpu-pool", _tpu_factory("tpu-pool", mixed=False))
register_substrate("tpu-pool-mixed",
                   _tpu_factory("tpu-pool-mixed", mixed=True))
register_substrate("gpu-pool", _gpu_factory("gpu-pool", mixed=False))
register_substrate("gpu-pool-mixed",
                   _gpu_factory("gpu-pool-mixed", mixed=True))
register_substrate("cxl-tier", _cxl_factory)
register_substrate("cxl-tier-3", _cxl3_factory)
register_substrate("cxl-tier-3-mixed", _cxl3_mixed_factory)
