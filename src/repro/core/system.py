"""End-to-end HH-PIM system simulation: scenarios -> energy/latency traces."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import spaces as sp
from repro.core import workloads
from repro.core.baselines import make_baseline_scheduler
from repro.core.energy import EnergyModel
from repro.core.scheduler import SliceReport, TimeSliceScheduler


@dataclasses.dataclass
class ScenarioResult:
    arch: str
    model: str
    scenario: str
    energy_uj: float
    deadline_miss: int
    reports: List[SliceReport]


def default_t_slice_ns(model: sp.ModelSpec, rho: float = 1.0,
                       headroom: float = 1.01) -> float:
    """Time slice sized to fit PEAK_TASKS inferences at HH-PIM peak perf
    (paper: 'up to 10 inferences per time slice'), plus 1% headroom so a
    placement migration can be absorbed in a full-load slice."""
    em = EnergyModel(sp.hh_pim(), model, rho=rho)
    t_peak = em.task_cost(em.peak_placement(sram_only=True)).t_task_ns
    return t_peak * workloads.PEAK_TASKS * headroom


def run_hh_pim(model: sp.ModelSpec, scenario: str, *, rho: float = 1.0,
               t_slice_ns: Optional[float] = None,
               lut_points: int = 64) -> ScenarioResult:
    t_slice = t_slice_ns or default_t_slice_ns(model, rho)
    sched = TimeSliceScheduler(sp.hh_pim(), model, t_slice_ns=t_slice,
                               rho=rho, lut_points=lut_points)
    reports = sched.run(workloads.SCENARIOS[scenario])
    return ScenarioResult(
        "hh_pim", model.name, scenario,
        sum(r.energy_pj for r in reports) * 1e-6,
        sum(not r.deadline_met for r in reports), reports)


def run_baseline(kind: str, model: sp.ModelSpec, scenario: str, *,
                 rho: float = 1.0, t_slice_ns: Optional[float] = None
                 ) -> ScenarioResult:
    t_slice = t_slice_ns or default_t_slice_ns(model, rho)
    sched = make_baseline_scheduler(kind, model, t_slice_ns=t_slice, rho=rho)
    reports = sched.run(workloads.SCENARIOS[scenario])
    return ScenarioResult(
        f"{kind}_pim", model.name, scenario,
        sum(r.energy_pj for r in reports) * 1e-6,
        sum(not r.deadline_met for r in reports), reports)


def energy_savings_table(model: sp.ModelSpec, *, rho: float = 1.0,
                         lut_points: int = 64
                         ) -> Dict[str, Dict[str, float]]:
    """Savings of HH-PIM vs each comparison arch per scenario (Fig. 5)."""
    t_slice = default_t_slice_ns(model, rho)
    out: Dict[str, Dict[str, float]] = {}
    for scen in workloads.SCENARIOS:
        hh = run_hh_pim(model, scen, rho=rho, t_slice_ns=t_slice,
                        lut_points=lut_points)
        row = {}
        for kind in ("baseline", "hetero", "hybrid"):
            base = run_baseline(kind, model, scen, rho=rho,
                                t_slice_ns=t_slice)
            row[kind] = 100.0 * (1.0 - hh.energy_uj / base.energy_uj)
        row["hh_energy_uj"] = hh.energy_uj
        out[scen] = row
    return out
