"""End-to-end HH-PIM system simulation: scenarios -> energy/latency traces.

All runtimes are constructed through the ``repro.api`` facade; ``kind``
and ``solver`` select substrate/solver registry entries, so adding an
arch variant or placement strategy needs no change here.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import spaces as sp
from repro.core import workloads
from repro.core.scheduler import SliceReport


@dataclasses.dataclass
class ScenarioResult:
    arch: str
    model: str
    scenario: str
    energy_uj: float
    deadline_miss: int
    reports: List[SliceReport]


def default_t_slice_ns(model: sp.ModelSpec, rho: float = 1.0,
                       headroom: float = 1.01) -> float:
    """Time slice sized to fit PEAK_TASKS inferences at HH-PIM peak perf
    (paper: 'up to 10 inferences per time slice'), plus 1% headroom so a
    placement migration can be absorbed in a full-load slice."""
    from repro.core.substrate import make_substrate
    return make_substrate("edge-hhpim").default_t_slice_ns(
        model, rho=rho, headroom=headroom)


def _run_scenario(sched, arch_tag: str, model: sp.ModelSpec, scenario: str
                  ) -> ScenarioResult:
    reports = sched.run(workloads.SCENARIOS[scenario])
    return ScenarioResult(
        arch_tag, model.name, scenario,
        sum(r.energy_pj for r in reports) * 1e-6,
        sum(not r.deadline_met for r in reports), reports)


def run_hh_pim(model: sp.ModelSpec, scenario: str, *, rho: float = 1.0,
               t_slice_ns: Optional[float] = None,
               lut_points: int = 64,
               solver: Optional[str] = None) -> ScenarioResult:
    from repro import api
    t_slice = t_slice_ns or default_t_slice_ns(model, rho)
    sched = api.scheduler("edge-hhpim", model, t_slice_ns=t_slice, rho=rho,
                          lut_points=lut_points, solver=solver)
    return _run_scenario(sched, "hh_pim", model, scenario)


def run_baseline(kind: str, model: sp.ModelSpec, scenario: str, *,
                 rho: float = 1.0, t_slice_ns: Optional[float] = None
                 ) -> ScenarioResult:
    from repro import api
    t_slice = t_slice_ns or default_t_slice_ns(model, rho)
    sched = api.scheduler(f"edge-{kind}", model, t_slice_ns=t_slice,
                          rho=rho)
    return _run_scenario(sched, f"{kind}_pim", model, scenario)


def energy_savings_table(model: sp.ModelSpec, *, rho: float = 1.0,
                         lut_points: int = 64
                         ) -> Dict[str, Dict[str, float]]:
    """Savings of HH-PIM vs each comparison arch per scenario (Fig. 5)."""
    t_slice = default_t_slice_ns(model, rho)
    out: Dict[str, Dict[str, float]] = {}
    for scen in workloads.SCENARIOS:
        hh = run_hh_pim(model, scen, rho=rho, t_slice_ns=t_slice,
                        lut_points=lut_points)
        row = {}
        for kind in ("baseline", "hetero", "hybrid"):
            base = run_baseline(kind, model, scen, rho=rho,
                                t_slice_ns=t_slice)
            row[kind] = 100.0 * (1.0 - hh.energy_uj / base.energy_uj)
        row["hh_energy_uj"] = hh.energy_uj
        out[scen] = row
    return out
