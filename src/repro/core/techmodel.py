"""``repro.core.techmodel`` - the technology/DVFS axis of a substrate
(DESIGN.md SS.10).

A :class:`TechModel` carries the per-tech-node physics every DVFS-capable
substrate shares: the vdd/frequency scaling curve, the dynamic-energy and
leakage scale it implies, and the DVFS upper/lower bounds the silicon
supports (after lumos' per-node ITRS/conservative scaling tables with
``DVFS_U_BOUND``/``DVFS_L_BOUND``; see ROADMAP + PAPERS.md). Before this
module, each serving substrate open-coded a single ``V^2 . f`` knob
(``repro.serve.gpu.dvfs_energy_scale``); now ``gpu-pool`` and both CXL
substrates resolve one registered model, so the frequency axis has one
source of truth the solver layer can enumerate.

On top of it sits the :class:`DVFSController`: the *online* half of the
paper's adaptive-allocation move, extended to the frequency axis. The
placement LUTs the fleet already builds are per-DVFS-point (the clock is
part of ``variant_key()``); the controller builds a small grid of them
through the shared :class:`~repro.core.compiler.PlacementCompiler`
(deduped fleet-wide exactly like every other build) and, each slice,
picks the energy-minimal ``(placement, clock)`` pair that still meets
the slice's latency budget. ``--dvfs`` stops being a static flag: the
clock becomes a solved variable (``TimeSliceScheduler.step`` consults
the controller when one is attached, and reports the chosen clock).

Clock transitions are modeled as free: a PLL relock is ~us against the
ms-scale slices every substrate runs, and no weights move when only the
frequency changes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence, Tuple

#: canonical rounding of a clock point (matches the ``lp_clock`` rounding
#: in ``ServePoolSubstrate.variant_key`` so grid points and cache keys
#: always agree)
CLOCK_DECIMALS = 4


@dataclasses.dataclass(frozen=True)
class TechModel:
    """Per-tech-node voltage/frequency/power scaling with DVFS bounds.

    The curve is the standard linear voltage-frequency tracking model
    down to a retention floor (the same shape the paper's 1.2 V / 0.8 V
    HP/LP split instantiates):

        ``vdd(clock) = v_min_frac + (1 - v_min_frac) * clock``

    with per-op switching energy going as ``V^2`` (:meth:`energy_scale`),
    dynamic *power* as ``V^2 . f`` (:meth:`power_scale`) and leakage as
    ``V^2`` too (:meth:`leakage_scale`; DIBL-dominated at these nodes -
    and identical to the dynamic scale on purpose, preserving the exact
    arithmetic the pre-TechModel substrates applied to their static
    rails, so LUTs at the legacy default clock stay byte-identical).

    ``dvfs_min``/``dvfs_max`` bound the *operating* range the controller
    may pick from (lumos' DVFS_L/U_BOUND); :meth:`energy_scale` itself
    accepts any clock in (0, 1] so explicitly constructed out-of-range
    substrates keep raising only at true physics violations.
    """

    name: str
    tech_nm: int                 # process node (informational + key)
    v_min_frac: float = 0.45     # voltage floor, fraction of nominal rail
    dvfs_min: float = 0.30       # lower DVFS frequency-scale bound
    dvfs_max: float = 1.00       # upper bound (nominal; no overdrive)

    def __post_init__(self):
        if not 0.0 < self.dvfs_min <= self.dvfs_max <= 1.0:
            raise ValueError(
                f"DVFS bounds must satisfy 0 < dvfs_min <= dvfs_max <= 1, "
                f"got [{self.dvfs_min}, {self.dvfs_max}]")
        if not 0.0 < self.v_min_frac <= 1.0:
            raise ValueError(f"v_min_frac must be in (0, 1], got "
                             f"{self.v_min_frac}")

    # -- vdd/frequency curve ----------------------------------------------
    def vdd(self, clock: float) -> float:
        """Rail voltage (fraction of nominal) at frequency scale
        ``clock`` - voltage tracks frequency linearly down to the
        retention floor."""
        self._check(clock)
        return self.v_min_frac + (1.0 - self.v_min_frac) * clock

    # -- dynamic + leakage power model ------------------------------------
    def energy_scale(self, clock: float) -> float:
        """Per-op dynamic (switching) energy scale: ``V^2`` at the
        frequency-matched voltage. The single physics expression behind
        ``repro.serve.gpu.dvfs_energy_scale`` (kept byte-identical)."""
        v = self.vdd(clock)
        return v * v

    def power_scale(self, clock: float) -> float:
        """Dynamic *power* scale ``C . V^2 . f`` (energy scale times
        throughput) - the frontier axis the 2-D sweep plots."""
        return self.energy_scale(clock) * clock

    def leakage_scale(self, clock: float) -> float:
        """Static/leakage power scale at ``clock``'s rail voltage.

        Modeled as ``V^2`` (identical to :meth:`energy_scale`): the
        pre-TechModel substrates scaled their static rails by the same
        factor as the dynamic energy, and keeping the expressions equal
        is what pins LUT bytes at the legacy default clock."""
        return self.energy_scale(clock)

    # -- DVFS bounds -------------------------------------------------------
    def in_bounds(self, clock: float) -> bool:
        return self.dvfs_min - 1e-12 <= clock <= self.dvfs_max + 1e-12

    def clamp(self, clock: float) -> float:
        """Clamp ``clock`` into the model's operating range."""
        return min(max(float(clock), self.dvfs_min), self.dvfs_max)

    def clock_grid(self, n_clocks: int = 5,
                   include: Iterable[float] = ()) -> Tuple[float, ...]:
        """``n_clocks`` evenly spaced operating points spanning
        [``dvfs_min``, ``dvfs_max``], merged (sorted, deduplicated at
        :data:`CLOCK_DECIMALS`) with any explicit ``include`` points -
        pass a substrate's default clock so the legacy static point is
        always on the solved grid."""
        if n_clocks < 1:
            raise ValueError("clock_grid needs n_clocks >= 1")
        if n_clocks == 1:
            pts = [self.dvfs_max]
        else:
            step = (self.dvfs_max - self.dvfs_min) / (n_clocks - 1)
            pts = [self.dvfs_min + i * step for i in range(n_clocks)]
        pts.extend(self.clamp(c) for c in include)
        seen: Dict[float, float] = {}
        for p in pts:
            seen.setdefault(round(p, CLOCK_DECIMALS), p)
        return tuple(seen[k] for k in sorted(seen))

    def _check(self, clock: float) -> None:
        if not 0.0 < clock <= 1.0:
            raise ValueError(
                f"DVFS clock scale must be in (0, 1], got {clock}")


# ---------------------------------------------------------------------------
# Registry (one entry per substrate technology; DESIGN.md SS.10)
# ---------------------------------------------------------------------------

TECH_MODELS: Dict[str, TechModel] = {}


def register_tech_model(model: TechModel) -> TechModel:
    TECH_MODELS[model.name] = model
    return model


def get_tech_model(name: str) -> TechModel:
    if name not in TECH_MODELS:
        raise ValueError(
            f"unknown tech model {name!r}; one of {sorted(TECH_MODELS)}")
    return TECH_MODELS[name]


def available_tech_models() -> Tuple[str, ...]:
    return tuple(sorted(TECH_MODELS))


#: A100-class SM pools (repro.serve.gpu): v_min_frac is the historic
#: ``V_MIN_FRAC = 0.45`` retention floor, bounds span the lp_clock range
#: the DVFS sweeps always used.
SM_POOL_7NM = register_tech_model(TechModel(
    "sm-pool-7nm", tech_nm=7, v_min_frac=0.45,
    dvfs_min=0.30, dvfs_max=1.00))

#: DDR5/CXL-class node pools (repro.serve.cxl, both cxl-tier and
#: cxl-tier-3): historically shared the GPU voltage curve (cxl.py
#: imported ``dvfs_energy_scale``), so the same v_min_frac - only the
#: lower operating bound differs (node fabrics hold a higher floor).
CXL_NODE_10NM = register_tech_model(TechModel(
    "cxl-node-10nm", tech_nm=10, v_min_frac=0.45,
    dvfs_min=0.35, dvfs_max=1.00))


# ---------------------------------------------------------------------------
# Online DVFS controller
# ---------------------------------------------------------------------------


class DVFSController:
    """Per-slice joint ``(placement, clock)`` solver for one engine.

    Holds one substrate variant per clock grid point (built with
    ``substrate.with_clock``), lazily materializes each point's
    :class:`~repro.core.energy.EnergyModel` + placement LUT through the
    shared :class:`~repro.core.compiler.PlacementCompiler` (clocked
    variants have distinct ``variant_key()``s, so N engines on the same
    grid pay one build per point fleet-wide), and per slice returns the
    grid point whose LUT placement minimizes *slice* energy subject to
    the slice's latency budget ``n_plan * t_task <= T``.

    Deterministic by construction: grid points are scanned in ascending
    clock order with strict improvement, so ties go to the lowest clock
    and identical inputs always produce identical clock sequences.
    """

    def __init__(self, substrate, workload=None, *,
                 clocks: Optional[Sequence[float]] = None,
                 n_clocks: int = 5,
                 t_slice_ns: Optional[float] = None,
                 rho: Optional[float] = None,
                 solver=None,
                 lut_points: Optional[int] = None,
                 compiler=None):
        tm = substrate.tech_model()
        if tm is None:
            raise ValueError(
                f"substrate {substrate.name!r} has no registered TechModel "
                f"(no DVFS axis to solve); use a gpu-pool or cxl-tier "
                f"substrate, or register one via its `tech` attribute")
        if compiler is None:
            from repro.core.compiler import PlacementCompiler
            compiler = PlacementCompiler()
        self.tech = tm
        self.base = substrate
        self.compiler = compiler
        default_clock = getattr(substrate, "lp_clock", None)
        if clocks is None:
            include = () if default_clock is None else (default_clock,)
            clocks = tm.clock_grid(n_clocks, include=include)
        else:
            clocks = tuple(sorted(tm.clamp(c) for c in clocks))
        self.clocks: Tuple[float, ...] = tuple(clocks)
        self.variants = {c: substrate.with_clock(c) for c in self.clocks}
        self.model = substrate.model_spec(workload)
        self.rho = substrate.rho if rho is None else rho
        self.solver = solver or substrate.solver
        self.lut_points = (substrate.lut_points if lut_points is None
                           else lut_points)
        self.t_slice_ns = float(
            substrate.default_t_slice_ns(self.model, rho=self.rho)
            if t_slice_ns is None else t_slice_ns)
        # (clock, slowdown signature) -> EnergyModel; LUTs live in the
        # shared compiler cache keyed the same way
        self._ems: Dict[tuple, object] = {}

    # -- per-point state ---------------------------------------------------
    def _em_for(self, clock: float, slowdown: Optional[dict]):
        from repro.core.compiler import slowdown_signature
        from repro.core.energy import EnergyModel
        key = (round(clock, CLOCK_DECIMALS),
               slowdown_signature(slowdown or {}))
        em = self._ems.get(key)
        if em is None:
            em = EnergyModel(self.variants[clock].arch, self.model,
                             rho=self.rho, time_scale=slowdown)
            self._ems[key] = em
        return em

    def lut_for(self, clock: float, slowdown: Optional[dict] = None):
        """The clock point's placement LUT, served from the fleet-wide
        compiler cache (straggler slowdowns get their own entries, keyed
        exactly like the scheduler's rebuilds)."""
        v = self.variants[clock]
        return self.compiler.lut(
            self._em_for(clock, slowdown), solver=self.solver,
            t_slice_ns=self.t_slice_ns, n_points=self.lut_points,
            static_window=v.static_window, variant_key=v.variant_key())

    def prepare(self) -> int:
        """Eagerly build every grid point's LUT (fleet bring-up pays the
        whole grid once; later engines on the same grid hit the cache).
        Returns the number of grid points."""
        for c in self.clocks:
            self.lut_for(c)
        return len(self.clocks)

    # -- the per-slice solve ----------------------------------------------
    def select(self, n_plan: int, *, slowdown: Optional[dict] = None):
        """Energy-minimal ``(clock, em, lut, entry)`` for a slice that
        must fit ``n_plan`` tasks into ``t_slice_ns``.

        Scores each grid point by exact slice energy under its LUT's
        placement (``n . e_dyn + statics over T``), skipping points whose
        placement cannot meet the budget. If no point fits (overload),
        falls back to the throughput-maximal point so the backlog drains
        fastest - the same degradation semantics as a static clock.
        """
        T = self.t_slice_ns
        n = max(int(n_plan), 1)
        best = fastest = None
        best_e = fastest_t = float("inf")
        for c in self.clocks:
            em = self._em_for(c, slowdown)
            lut = self.lut_for(c, slowdown)
            entry = lut.lookup(T / n)
            cost = em.task_cost(entry.placement)
            cand = (c, em, lut, entry)
            if cost.t_task_ns < fastest_t:
                fastest_t, fastest = cost.t_task_ns, cand
            if n * cost.t_task_ns > T * (1 + 1e-9):
                continue
            busy = {k: v * n for k, v in cost.t_cluster_ns.items()}
            e_slice = (n * cost.e_dyn_task_pj
                       + em.static_energy_pj(entry.placement, T, busy))
            if e_slice < best_e:
                best_e, best = e_slice, cand
        return best if best is not None else fastest
