"""Workload scenarios of Fig. 4: tasks (inferences) arriving per time slice.

Six patterns over 50 slices, peak load 10 inferences/slice (the paper sets
the time slice to fit up to 10 inferences at maximum performance).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

N_SLICES = 50
PEAK_TASKS = 10
LOW_TASKS = 2


def case1_low_constant(n: int = N_SLICES) -> List[int]:
    return [LOW_TASKS] * n


def case2_high_constant(n: int = N_SLICES) -> List[int]:
    return [PEAK_TASKS] * n


def case3_periodic_spike(n: int = N_SLICES, period: int = 10,
                         width: int = 2) -> List[int]:
    return [PEAK_TASKS if (i % period) < width else LOW_TASKS
            for i in range(n)]


def case4_periodic_spike_frequent(n: int = N_SLICES, period: int = 4,
                                  width: int = 1) -> List[int]:
    return [PEAK_TASKS if (i % period) < width else LOW_TASKS
            for i in range(n)]


def case5_pulsing(n: int = N_SLICES, half_period: int = 5) -> List[int]:
    return [PEAK_TASKS if (i // half_period) % 2 == 0 else LOW_TASKS
            for i in range(n)]


def case6_random(n: int = N_SLICES, seed: int = 0) -> List[int]:
    rng = np.random.default_rng(seed)
    return [int(x) for x in rng.integers(1, PEAK_TASKS + 1, size=n)]


SCENARIOS: Dict[str, List[int]] = {
    "case1_low_constant": case1_low_constant(),
    "case2_high_constant": case2_high_constant(),
    "case3_periodic_spike": case3_periodic_spike(),
    "case4_periodic_spike_frequent": case4_periodic_spike_frequent(),
    "case5_pulsing": case5_pulsing(),
    "case6_random": case6_random(),
}
