"""Deterministic synthetic LM data pipeline: seeded, step-indexed, sharded.

Stateless by construction - batch ``i`` is a pure function of (seed, i) - so
a restarted job resumes mid-epoch exactly (fault tolerance requirement),
and each data shard draws only its slice (no host reads the global batch).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-chain-ish structure so the tiny-train example has learnable
    # signal (pure uniform noise has no decreasing loss)
    structure: float = 0.8


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # a fixed random transition table: next-token = f(prev) w.p.
        # `structure`, else uniform
        self._next = rng.integers(0, cfg.vocab_size,
                                  size=cfg.vocab_size).astype(np.int32)

    def batch(self, step: int, shard: int = 0, num_shards: int = 1
              ) -> Dict[str, np.ndarray]:
        """Batch for `step`, restricted to this host's shard rows."""
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        rows = cfg.global_batch // num_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + shard)
        toks = np.empty((rows, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=rows)
        flip = rng.random((rows, cfg.seq_len)) < cfg.structure
        rand = rng.integers(0, cfg.vocab_size, size=(rows, cfg.seq_len))
        for t in range(cfg.seq_len):
            toks[:, t + 1] = np.where(flip[:, t], self._next[toks[:, t]],
                                      rand[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterate(self, start_step: int = 0, shard: int = 0,
                num_shards: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, shard, num_shards)
            step += 1
