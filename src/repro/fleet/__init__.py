"""repro.fleet - trace-driven multi-engine serving fleet.

Generalizes the paper's per-device time-slice placement loop to a pool of
N serve engines fed by realistic traffic: arrival traces
(:mod:`repro.fleet.traces`), per-engine load forecasting
(:mod:`repro.fleet.forecast`) driving *proactive* weight migration through
the scheduler's ``lookup_tasks`` hook, an SLO-aware router with admission
control (:mod:`repro.fleet.router`), and tail-latency/energy aggregation
(:mod:`repro.fleet.metrics`).

Fleets are canonically constructed through ``repro.api.fleet`` (substrate
registry + shared placement LUT per engine shape; optionally a real
``HeteroServeEngine`` per worker so placements are functionally exercised
by decoding tokens through re-tiered weights). ``build_fleet`` remains as
a one-release deprecation shim over ``api.fleet("tpu-pool[-mixed]")``.
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro.fleet.forecast import (FORECASTERS, Forecaster,  # noqa: F401
                                  make_forecaster)
from repro.fleet.metrics import FleetSummary, summarize  # noqa: F401
from repro.fleet.router import (POLICIES, EngineWorker,  # noqa: F401
                                Fleet, FleetRequest, FleetResult,
                                FleetRouter)
from repro.fleet.traces import (BURSTY, TRACES, Trace,  # noqa: F401
                                make_trace)

__all__ = [
    "Trace", "make_trace", "TRACES", "BURSTY",
    "Forecaster", "make_forecaster", "FORECASTERS",
    "EngineWorker", "FleetRouter", "Fleet", "FleetRequest", "FleetResult",
    "POLICIES", "FleetSummary", "summarize", "build_fleet",
]


def build_fleet(cfg=None, *, n_engines: int = 2, forecaster: str = "ewma",
                policy: str = "slo", hp_chips: int = 4, lp_chips: int = 4,
                mixed: bool = False, tokens_per_task: int = 2,
                rho: float = 64.0, t_slice_ms: Optional[float] = None,
                lut_points: int = 32, admission_limit: Optional[int] = None,
                slo_slices: float = 2.0, forecast_margin: float = 1.0,
                params=None, decode: bool = False, max_batch: int = 16,
                forecaster_kw: Optional[dict] = None) -> Fleet:
    """Deprecated shim: construct through ``repro.api.fleet`` instead.

    ``mixed=True`` maps to the ``tpu-pool-mixed`` substrate (odd-indexed
    engines get half the chips); everything else forwards unchanged.
    """
    warnings.warn(
        "build_fleet is deprecated; use repro.api.fleet("
        "'tpu-pool' / 'tpu-pool-mixed', ...) instead (DESIGN.md SS.5)",
        DeprecationWarning, stacklevel=2)
    from repro import api
    return api.fleet(
        "tpu-pool-mixed" if mixed else "tpu-pool", cfg,
        n_engines=n_engines, forecaster=forecaster, policy=policy,
        tokens_per_task=tokens_per_task, rho=rho, t_slice_ms=t_slice_ms,
        lut_points=lut_points, admission_limit=admission_limit,
        slo_slices=slo_slices, forecast_margin=forecast_margin,
        params=params, decode=decode, max_batch=max_batch,
        forecaster_kw=forecaster_kw,
        n_hp_chips=hp_chips, n_lp_chips=lp_chips)
