"""repro.fleet - trace-driven multi-engine serving fleet.

Generalizes the paper's per-device time-slice placement loop to a pool of
N serve engines fed by realistic traffic: arrival traces
(:mod:`repro.fleet.traces`), per-engine load forecasting
(:mod:`repro.fleet.forecast`) driving *proactive* weight migration through
the scheduler's ``lookup_tasks`` hook, an SLO-aware router with admission
control (:mod:`repro.fleet.router`), and tail-latency/energy aggregation
(:mod:`repro.fleet.metrics`).

``build_fleet`` wires everything for the TPU parameterization of
``repro.serve.hetero`` (shared placement LUT across identical engines;
optionally a real ``HeteroServeEngine`` per worker so placements are
functionally exercised by decoding tokens through re-tiered weights).
"""
from __future__ import annotations

from typing import Optional

from repro.core import workloads
from repro.core.placement import build_lut
from repro.core.scheduler import TimeSliceScheduler
from repro.fleet.forecast import (FORECASTERS, Forecaster,  # noqa: F401
                                  make_forecaster)
from repro.fleet.metrics import FleetSummary, summarize  # noqa: F401
from repro.fleet.router import (POLICIES, EngineWorker,  # noqa: F401
                                Fleet, FleetRequest, FleetResult,
                                FleetRouter)
from repro.fleet.traces import (BURSTY, TRACES, Trace,  # noqa: F401
                                make_trace)

__all__ = [
    "Trace", "make_trace", "TRACES", "BURSTY",
    "Forecaster", "make_forecaster", "FORECASTERS",
    "EngineWorker", "FleetRouter", "Fleet", "FleetRequest", "FleetResult",
    "POLICIES", "FleetSummary", "summarize", "build_fleet",
]


def build_fleet(cfg=None, *, n_engines: int = 2, forecaster: str = "ewma",
                policy: str = "slo", hp_chips: int = 4, lp_chips: int = 4,
                mixed: bool = False, tokens_per_task: int = 2,
                rho: float = 64.0, t_slice_ms: Optional[float] = None,
                lut_points: int = 32, admission_limit: Optional[int] = None,
                slo_slices: float = 2.0, forecast_margin: float = 1.0,
                params=None, decode: bool = False, max_batch: int = 16,
                forecaster_kw: Optional[dict] = None) -> Fleet:
    """Construct a fleet of ``n_engines`` TPU-parameterized serve engines.

    ``mixed=True`` builds a heterogeneous pool (odd-indexed engines get half
    the chips), which is where the ``slo`` routing policy earns its keep.
    ``decode=True`` attaches a real ``HeteroServeEngine`` (requires
    ``params``) per worker: every slice's placement is applied as an actual
    weight re-tiering and one decode step runs through the tiered model.
    """
    from repro.serve.hetero import tpu_arch, tpu_model_spec

    if cfg is None:
        from repro.configs import get_smoke_config
        cfg = get_smoke_config("internlm2_1_8b")
    model = tpu_model_spec(cfg, tokens_per_task)

    chip_plan = []
    for i in range(n_engines):
        if mixed and i % 2 == 1:
            chip_plan.append((max(hp_chips // 2, 1), max(lp_chips // 2, 1)))
        else:
            chip_plan.append((hp_chips, lp_chips))
    archs = {plan: tpu_arch(*plan) for plan in set(chip_plan)}

    if t_slice_ms is None:
        # fleet-wide slice = the fastest engine shape's default sizing
        from repro.serve.hetero import default_t_slice_ms
        t_slice_ms = min(
            default_t_slice_ms(a, model, rho=rho,
                               peak_tasks=workloads.PEAK_TASKS)
            for a in archs.values())
    t_slice_ns = t_slice_ms * 1e6

    # one LUT per distinct engine shape, shared by all its instances
    luts = {plan: build_lut(arch, model, t_slice_ns=t_slice_ns, rho=rho,
                            n_points=lut_points)
            for plan, arch in archs.items()}

    workers = []
    for i, plan in enumerate(chip_plan):
        hetero = None
        if decode:
            from repro.serve.hetero import HeteroServeEngine
            if params is None:
                raise ValueError("decode=True requires model params")
            hetero = HeteroServeEngine(
                cfg, params, t_slice_ms=t_slice_ns / 1e6,
                n_hp_chips=plan[0], n_lp_chips=plan[1],
                tokens_per_task=tokens_per_task, rho=rho,
                max_batch=max_batch)
            sched = hetero.sched
            sched._lut_cache[sched._slowdown_key()] = luts[plan]
        else:
            sched = TimeSliceScheduler(
                archs[plan], model, t_slice_ns=t_slice_ns, rho=rho,
                lut=luts[plan], lut_points=lut_points)
        workers.append(EngineWorker(
            i, sched, make_forecaster(forecaster, **(forecaster_kw or {})),
            hetero=hetero, forecast_margin=forecast_margin))
    return Fleet(workers, policy=policy, admission_limit=admission_limit,
                 slo_slices=slo_slices, tokens_per_request=tokens_per_task)
