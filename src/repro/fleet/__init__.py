"""repro.fleet - trace-driven multi-engine serving fleet.

Generalizes the paper's per-device time-slice placement loop to a pool of
N serve engines fed by realistic traffic: arrival traces
(:mod:`repro.fleet.traces`), per-engine load forecasting
(:mod:`repro.fleet.forecast`) driving *proactive* weight migration through
the scheduler's ``lookup_tasks`` hook, an SLO-aware router with admission
control (:mod:`repro.fleet.router`), the two-level cell router +
autoscaler that scale the loop to hundreds->thousands of engines
(:mod:`repro.fleet.hierarchy`, DESIGN.md SS.9), and tail-latency/energy
aggregation (:mod:`repro.fleet.metrics`).

Fleets are canonically constructed through ``repro.api.fleet`` (flat) and
``repro.api.hierarchical_fleet`` (cells): substrate registry + shared
placement LUT per engine shape; optionally a real ``HeteroServeEngine``
per worker so placements are functionally exercised by decoding tokens
through re-tiered weights.
"""
from __future__ import annotations

from repro.fleet.dag import (DAG_SPECS, DagCoScheduler,  # noqa: F401
                             DagFleet, DagRequest, DagResult, DagSpec,
                             DagTrace, StageRequest, StageSpec, Tenant,
                             TenantRegistry, dag_arrivals,
                             default_tenants, make_dag_spec,
                             tenant_breakdown)
from repro.fleet.forecast import (FORECASTERS, Forecaster,  # noqa: F401
                                  make_forecaster)
from repro.fleet.hierarchy import (CELL_POLICIES,  # noqa: F401
                                   AutoscaleConfig, Cell, CellAutoscaler,
                                   CellRouter, HierarchicalFleet,
                                   HierarchyResult, ScaleEvent)
from repro.fleet.metrics import (FleetSummary, class_breakdown,  # noqa: F401
                                 summarize)
from repro.fleet.router import (POLICIES, EngineWorker,  # noqa: F401
                                Fleet, FleetRequest, FleetResult,
                                FleetRouter)
from repro.fleet.traces import (BURSTY, TRACES, Trace,  # noqa: F401
                                make_trace)

__all__ = [
    "Trace", "make_trace", "TRACES", "BURSTY",
    "Forecaster", "make_forecaster", "FORECASTERS",
    "EngineWorker", "FleetRouter", "Fleet", "FleetRequest", "FleetResult",
    "POLICIES", "FleetSummary", "summarize", "class_breakdown",
    "Cell", "CellRouter", "CellAutoscaler", "AutoscaleConfig",
    "HierarchicalFleet", "HierarchyResult", "ScaleEvent", "CELL_POLICIES",
    "DagSpec", "StageSpec", "DAG_SPECS", "make_dag_spec",
    "Tenant", "TenantRegistry", "default_tenants",
    "DagTrace", "dag_arrivals", "DagRequest", "StageRequest",
    "DagCoScheduler", "DagFleet", "DagResult", "tenant_breakdown",
]
