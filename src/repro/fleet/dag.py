"""``repro.fleet.dag`` - multi-tenant DAG workloads + stage co-scheduling.

The fleet so far serves independent single-request streams; this module
models requests as *pipelines* - DAGs of named stages with per-stage
token counts, compute classes and residency hints (after lumos-style
application modeling and the heterogeneous data-centric survey in
PAPERS.md) - and schedules *stages* rather than requests, so each stage
lands on the (cell, substrate) pool that suits it:

* :class:`StageSpec` / :class:`DagSpec` describe the workload shape;
  canonical specs ship for ``prefill_decode`` (prefill -> decode),
  ``agentic`` (prefill -> decode -> tool_call -> decode) and the
  two-model ``draft_verify`` pipeline. Specs validate at construction:
  duplicate stages, dangling edges and cycles all raise shaped errors.
* :class:`Tenant` / :class:`TenantRegistry` map tenants to an SLO
  class, an optional per-tenant budget override, an admission weight
  and the DAG spec their requests instantiate. Unknown tenants and
  unregistered SLO classes raise shaped errors naming the offender and
  listing what is registered (no silent defaults).
* :func:`dag_arrivals` layers seeded tenant draws on the existing
  arrival processes (:mod:`repro.fleet.traces`), producing a
  :class:`DagTrace` - per-slice lists of arriving tenants; equal seeds
  give equal traces.
* :class:`DagCoScheduler` places ready stages (topological frontier) on
  cells, scored by expected queue latency over the tenant's budget, the
  stage's energy/token on that cell's substrate - read from the
  placement LUTs already compiled at fleet bring-up via
  :meth:`~repro.core.scheduler.TimeSliceScheduler.stage_cost` (the SS.6
  variant-key cache; a DAG fleet pays **zero** LUT builds beyond the
  per-variant set a plain fleet of the same substrates pays) - plus a
  fixed per-edge handoff latency/energy tax when a stage runs in a
  different cell than its parent, and an optional residency-hint bonus.
* :class:`DagFleet` extends :class:`~repro.fleet.hierarchy.
  HierarchicalFleet`: :meth:`DagFleet.run_dag` drives a
  :class:`DagTrace` (optionally with a plain background
  :class:`~repro.fleet.traces.Trace` routed through the same cells, so
  DAG stages and plain requests coexist in one fleet) and returns a
  :class:`DagResult` whose stage-level
  :class:`~repro.fleet.router.FleetResult` works with
  :func:`repro.fleet.metrics.summarize` unchanged.

Construct through :func:`repro.api.dag_fleet`; the fleet CLI exposes
``--workload dag:<spec>``. See DESIGN.md SS.11.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.fleet.hierarchy import Cell, HierarchicalFleet
from repro.fleet.router import (ADMIT_ACCEPT, ADMIT_REJECT, FleetRequest,
                                FleetResult)
from repro.fleet.traces import Trace, make_trace

#: admission reject reason for a DAG whose root stage cannot meet the
#: tenant's budget in any cell (complements SS.8/SS.9 reason codes)
REASON_TENANT_BUDGET = "tenant_budget_exhausted"

#: stage lifecycle states on a DagRequest
PENDING, QUEUED, DONE = "pending", "queued", "done"


def _unknown(kind: str, name, registered: Iterable[str]) -> ValueError:
    """The shaped unknown-reference error: names the offender and lists
    what is registered (the satellite contract - no silent defaults)."""
    return ValueError(
        f"unknown {kind} {name!r}; registered: {sorted(registered)}")


# -- workload model ----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One named stage of a DAG request.

    ``tokens`` sizes the stage (decoded-token equivalents; the fleet
    splits it into ``ceil(tokens / tokens_per_task)`` scheduler tasks),
    ``compute_class`` labels its profile (prefill / decode / tool /
    draft / verify - attribution + future per-class costing), and
    ``residency`` optionally names a substrate-family hint (substring
    matched against a cell's substrate name; matching cells get a
    scoring bonus)."""
    name: str
    tokens: int
    compute_class: str = "decode"
    residency: Optional[str] = None

    def __post_init__(self):
        if self.tokens <= 0:
            raise ValueError(
                f"stage {self.name!r} needs tokens > 0, got {self.tokens}")


@dataclasses.dataclass(frozen=True)
class DagSpec:
    """A validated stage DAG: unique stage names, edges between known
    stages, acyclic (checked with Kahn's algorithm at construction; a
    cycle raises a shaped error naming its members)."""
    name: str
    stages: Tuple[StageSpec, ...]
    edges: Tuple[Tuple[str, str], ...] = ()

    def __post_init__(self):
        names = [s.name for s in self.stages]
        if not names:
            raise ValueError(f"dag {self.name!r} needs at least one stage")
        dups = sorted({n for n in names if names.count(n) > 1})
        if dups:
            raise ValueError(
                f"dag {self.name!r} has duplicate stage names {dups}")
        known = set(names)
        for u, v in self.edges:
            for end in (u, v):
                if end not in known:
                    raise _unknown(
                        f"stage (edge {u!r}->{v!r} of dag {self.name!r})",
                        end, known)
            if u == v:
                raise ValueError(
                    f"dag {self.name!r} has a self-edge on stage {u!r}")
        self.topo_order()                    # raises on cycles

    def stage(self, name: str) -> StageSpec:
        for s in self.stages:
            if s.name == name:
                return s
        raise _unknown(f"stage of dag {self.name!r}", name,
                       [s.name for s in self.stages])

    def parents(self, name: str) -> List[str]:
        return [u for u, v in self.edges if v == name]

    def children(self, name: str) -> List[str]:
        return [v for u, v in self.edges if u == name]

    def roots(self) -> List[str]:
        has_parent = {v for _, v in self.edges}
        return [s.name for s in self.stages if s.name not in has_parent]

    def topo_order(self) -> List[str]:
        """Deterministic topological order (Kahn; ties broken by spec
        order). Raises a shaped error when a cycle remains."""
        order_ix = {s.name: i for i, s in enumerate(self.stages)}
        indeg = {s.name: 0 for s in self.stages}
        for _, v in self.edges:
            indeg[v] += 1
        frontier = sorted((n for n, d in indeg.items() if d == 0),
                          key=order_ix.get)
        out: List[str] = []
        while frontier:
            n = frontier.pop(0)
            out.append(n)
            for c in self.children(n):
                indeg[c] -= 1
                if indeg[c] == 0:
                    frontier.append(c)
            frontier.sort(key=order_ix.get)
        if len(out) != len(self.stages):
            cycle = sorted(n for n, d in indeg.items() if d > 0)
            raise ValueError(
                f"dag {self.name!r} has a cycle through stages {cycle}; "
                f"edges must form a DAG")
        return out

    def critical_path_len(self) -> int:
        """Stages on the longest root->leaf path: the factor that scales
        a tenant's per-request SLO budget to a whole-DAG budget."""
        depth: Dict[str, int] = {}
        for n in self.topo_order():
            ps = self.parents(n)
            depth[n] = 1 + max((depth[p] for p in ps), default=0)
        return max(depth.values())

    @property
    def total_tokens(self) -> int:
        return sum(s.tokens for s in self.stages)


def prefill_decode_spec(*, prefill_tokens: int = 32,
                        decode_tokens: int = 8) -> DagSpec:
    """The canonical serving pipeline: one prefill stage feeding decode."""
    return DagSpec(
        "prefill_decode",
        (StageSpec("prefill", prefill_tokens, "prefill"),
         StageSpec("decode", decode_tokens, "decode")),
        (("prefill", "decode"),))


def agentic_spec(*, prefill_tokens: int = 32, decode_tokens: int = 8,
                 tool_tokens: int = 4) -> DagSpec:
    """Agentic chain: prefill -> decode -> tool_call -> decode (the
    second decode consumes the tool result)."""
    return DagSpec(
        "agentic",
        (StageSpec("prefill", prefill_tokens, "prefill"),
         StageSpec("decode", decode_tokens, "decode"),
         StageSpec("tool_call", tool_tokens, "tool"),
         StageSpec("decode2", decode_tokens, "decode")),
        (("prefill", "decode"), ("decode", "tool_call"),
         ("tool_call", "decode2")))


def draft_verify_spec(*, draft_tokens: int = 8,
                      verify_tokens: int = 16) -> DagSpec:
    """Two-model speculative pipeline: a cheap draft stage whose output
    a heavier verify stage checks (the compute classes attribute the
    two models; both run this fleet's model spec)."""
    return DagSpec(
        "draft_verify",
        (StageSpec("draft", draft_tokens, "draft"),
         StageSpec("verify", verify_tokens, "verify")),
        (("draft", "verify"),))


DAG_SPECS: Dict[str, DagSpec] = {
    "prefill_decode": prefill_decode_spec(),
    "agentic": agentic_spec(),
    "draft_verify": draft_verify_spec(),
}


def make_dag_spec(spec) -> DagSpec:
    """Resolve a canonical spec by name (instances pass through)."""
    if isinstance(spec, DagSpec):
        return spec
    if spec in DAG_SPECS:
        return DAG_SPECS[spec]
    raise _unknown("dag spec", spec, DAG_SPECS)


# -- tenants -----------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One tenant: SLO class, optional per-tenant budget override (in
    slices, per stage of critical path), admission weight (scales the
    wait-based admission headroom: > 1 admits deeper, < 1 shallower)
    and the DAG spec its requests instantiate."""
    name: str
    slo_class: str = "default"
    budget_slices: Optional[float] = None
    weight: float = 1.0
    dag: str = "prefill_decode"

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(
                f"tenant {self.name!r} needs weight > 0, got {self.weight}")
        make_dag_spec(self.dag)              # shaped error on unknown spec


class TenantRegistry:
    """Name-keyed tenant registry; lookups of unregistered tenants raise
    shaped errors listing the registered set."""

    def __init__(self, tenants: Sequence[Tenant] = ()):
        self._tenants: Dict[str, Tenant] = {}
        for t in tenants:
            self.register(t)

    def register(self, tenant: Tenant) -> Tenant:
        if tenant.name in self._tenants:
            raise ValueError(f"tenant {tenant.name!r} already registered")
        self._tenants[tenant.name] = tenant
        return tenant

    def get(self, name: str) -> Tenant:
        try:
            return self._tenants[name]
        except KeyError:
            raise _unknown("tenant", name, self._tenants) from None

    def names(self) -> List[str]:
        return sorted(self._tenants)

    def __iter__(self):
        return iter(self._tenants.values())

    def __len__(self) -> int:
        return len(self._tenants)

    def __contains__(self, name: str) -> bool:
        return name in self._tenants


def default_tenants() -> TenantRegistry:
    """The stock mixed-tenant registry the CLI and benches use: an
    interactive agentic tenant, a batch prefill/decode tenant and a
    lower-weight default-class draft/verify tenant."""
    return TenantRegistry((
        Tenant("acme", "interactive", weight=1.0, dag="agentic"),
        Tenant("batchco", "batch", weight=1.0, dag="prefill_decode"),
        Tenant("duo", "default", weight=0.5, dag="draft_verify"),
    ))


#: default budgets matching :func:`default_tenants` (slices per stage of
#: critical path; "default" inherits the fleet's slo_slices)
DEFAULT_DAG_BUDGETS = {"interactive": 3.0, "batch": 8.0}


# -- requests ----------------------------------------------------------------


@dataclasses.dataclass
class StageRequest(FleetRequest):
    """One scheduler task ("chunk") of a DAG stage; a stage with N
    tokens becomes ``ceil(N / tokens_per_task)`` chunks enqueued into
    the stage's chosen cell, and the stage completes when its last
    chunk does."""
    dag_rid: int = -1
    stage: str = ""
    chunk: int = 0
    n_chunks: int = 1


@dataclasses.dataclass
class DagRequest:
    """One in-flight DAG instance for a tenant."""
    rid: int
    tenant: str
    slo_class: str
    spec: DagSpec
    arrival_slice: int
    state: Dict[str, str] = dataclasses.field(default_factory=dict)
    cell_of: Dict[str, int] = dataclasses.field(default_factory=dict)
    queued_slice: Dict[str, int] = dataclasses.field(default_factory=dict)
    finish_slice_of: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: absolute ns of each stage's last chunk completion
    finish_ns_of: Dict[str, float] = dataclasses.field(default_factory=dict)
    chunks_left: Dict[str, int] = dataclasses.field(default_factory=dict)
    handoffs: int = 0
    rejected: bool = False
    finish_slice: Optional[int] = None
    latency_ns: Optional[float] = None

    def __post_init__(self):
        if not self.state:
            self.state = {s.name: PENDING for s in self.spec.stages}

    @property
    def done(self) -> bool:
        return all(v == DONE for v in self.state.values())

    def ready_stages(self) -> List[str]:
        """The topological frontier: pending stages whose parents are
        all complete, in deterministic topological order."""
        return [n for n in self.spec.topo_order()
                if self.state[n] == PENDING
                and all(self.state[p] == DONE for p in self.spec.parents(n))]


# -- traces ------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DagTrace:
    """Per-slice lists of arriving tenant names (each arrival is one
    DAG instance of that tenant's spec)."""
    name: str
    arrivals: List[List[str]]

    def __len__(self) -> int:
        return len(self.arrivals)

    @property
    def total(self) -> int:
        return sum(len(a) for a in self.arrivals)

    @property
    def counts(self) -> List[int]:
        return [len(a) for a in self.arrivals]


def dag_arrivals(tenants: TenantRegistry, n_slices: int = 50, *,
                 base: str = "mmpp", mix: Optional[Dict[str, float]] = None,
                 seed: int = 0, **kw) -> DagTrace:
    """Seeded DAG trace layered on an existing arrival process: per-slice
    counts come from :func:`repro.fleet.traces.make_trace` (``base`` +
    kwargs), and each arrival draws a tenant from ``mix`` (tenant ->
    probability weight; default: the registry's admission weights).
    Referencing an unregistered tenant raises a shaped error."""
    if not len(tenants):
        raise ValueError("dag_arrivals needs at least one tenant")
    if mix is None:
        mix = {t.name: t.weight for t in tenants}
    for name in mix:
        if name not in tenants:
            raise _unknown("tenant (in mix)", name, tenants.names())
    names = sorted(mix)
    total = sum(mix.values())
    probs = [mix[n] / total for n in names]
    counts = make_trace(base, n_slices=n_slices, seed=seed, **kw)
    rng = np.random.default_rng(seed + 1)
    arrivals = [[names[int(i)] for i in rng.choice(len(names), size=n,
                                                   p=probs)]
                for n in counts.arrivals]
    return DagTrace(f"dag-{counts.name}", arrivals)


# -- stage co-scheduler ------------------------------------------------------


class DagCoScheduler:
    """Places ready DAG stages on cells.

    Score of placing a stage on cell ``c`` (lower is better)::

        expected_latency(c, n_chunks) / budget
          + energy_weight * stage_energy_norm(c, stage)
          + handoff_tax_slices / budget   per parent in another cell
          - affinity_bonus                if the residency hint matches

    ``stage_energy_norm`` is the stage's energy/token on the cell's
    substrate - looked up through the engine scheduler's
    :meth:`~repro.core.scheduler.TimeSliceScheduler.stage_cost` hook
    against the placement LUT compiled at bring-up (SS.6 variant-key
    cache: **no** builds beyond the plain fleet's per-variant set) -
    min-max normalized across cells. With ``stage_affinity=False`` every
    non-root stage is pinned to its DAG's admission cell (request-level
    routing: the benchmark baseline)."""

    def __init__(self, cells: Sequence[Cell], *,
                 tokens_per_task: int = 2,
                 handoff_tax_slices: float = 0.25,
                 handoff_energy_pj: float = 2e5,
                 energy_weight: float = 0.05,
                 affinity_bonus: float = 0.1,
                 stage_affinity: bool = True):
        self.cells = list(cells)
        self.tokens_per_task = max(tokens_per_task, 1)
        self.handoff_tax_slices = handoff_tax_slices
        self.handoff_energy_pj = handoff_energy_pj
        self.energy_weight = energy_weight
        self.affinity_bonus = affinity_bonus
        self.stage_affinity = stage_affinity
        # (cid, n_tasks) -> energy/token pj; LUT-backed, static per run
        self._ecache: Dict[Tuple[int, int], float] = {}

    def n_chunks(self, spec: StageSpec) -> int:
        return max(math.ceil(spec.tokens / self.tokens_per_task), 1)

    def stage_energy_per_token(self, cell: Cell, spec: StageSpec) -> float:
        """Energy/token (pJ) the stage would pay on this cell, from the
        cell substrate's placement LUT at the stage's own load point."""
        n = self.n_chunks(spec)
        key = (cell.cid, n)
        if key not in self._ecache:
            _, e_task = cell.workers[0].sched.stage_cost(n)
            self._ecache[key] = e_task / self.tokens_per_task
        return self._ecache[key]

    def _scores(self, spec: StageSpec, budget: float,
                parent_cells: Sequence[int]) -> List[Tuple[float, float,
                                                           Cell]]:
        n = self.n_chunks(spec)
        es = [self.stage_energy_per_token(c, spec) for c in self.cells]
        lo, hi = min(es), max(es)
        spread = hi - lo
        scored = []
        for c, e in zip(self.cells, es):
            lat = c.expected_latency_slices(n)
            s = lat / budget
            s += self.energy_weight * ((e - lo) / spread if spread > 0
                                       else 0.0)
            s += sum(self.handoff_tax_slices / budget
                     for p in parent_cells if p != c.cid)
            if spec.residency and spec.residency in str(
                    getattr(c.substrate, "name", "")):
                s -= self.affinity_bonus
            scored.append((s, lat, c))
        scored.sort(key=lambda t: (t[0], t[2].cid))
        return scored

    def choose(self, dag: DagRequest, stage_name: str,
               budget: float) -> Cell:
        """Pick the cell for a ready stage (see class docstring)."""
        spec = dag.spec.stage(stage_name)
        parent_cells = [dag.cell_of[p]
                        for p in dag.spec.parents(stage_name)
                        if p in dag.cell_of]
        if not self.stage_affinity and parent_cells:
            # request-level routing baseline: follow the admission cell
            pinned = dag.cell_of[dag.spec.parents(stage_name)[0]]
            return next(c for c in self.cells if c.cid == pinned)
        return self._scores(spec, budget, parent_cells)[0][2]


# -- results -----------------------------------------------------------------


@dataclasses.dataclass
class DagResult:
    """Outcome of :meth:`DagFleet.run_dag`: DAG-level accounting plus
    the stage-level :class:`~repro.fleet.router.FleetResult` (chunk
    requests), so :func:`repro.fleet.metrics.summarize` applies to the
    stage stream unchanged."""
    trace: str
    completed: List[DagRequest]
    rejected: List[DagRequest]
    unfinished: List[DagRequest]
    stage_result: FleetResult
    #: (dag rid, stage, cell, slice queued) in placement order - the
    #: determinism contract: same trace + seed => identical sequence
    assignments: List[Tuple[int, str, int, int]]
    handoffs: int
    handoff_energy_pj: float
    background_result: Optional[FleetResult] = None

    @property
    def result(self) -> FleetResult:
        # summarize()/class_breakdown() unwrap via .result like
        # HierarchyResult; for a DAG run that is the stage stream
        return self.stage_result


def dag_budget_slices(dag: DagRequest, class_budget: float,
                      tenant: Tenant) -> float:
    """Whole-DAG latency budget in slices: the tenant's per-stage budget
    (override or SLO-class budget) times the spec's critical path."""
    per_stage = (tenant.budget_slices if tenant.budget_slices is not None
                 else class_budget)
    return per_stage * dag.spec.critical_path_len()


def tenant_breakdown(res: DagResult, fleet: "DagFleet") -> Dict[str, Dict]:
    """Per-tenant outcome stats for a DAG run (the CLI summary table and
    the bench's per-tenant columns)."""
    out: Dict[str, Dict] = {}
    T = res.stage_result.t_slice_ns
    groups: Dict[str, Dict[str, list]] = {}
    for d in res.completed:
        groups.setdefault(d.tenant, {"lat": [], "rej": 0, "unf": 0,
                                     "hand": 0, "miss": 0})
        g = groups[d.tenant]
        g["lat"].append(d.latency_ns)
        g["hand"] += d.handoffs
        t = fleet.tenants.get(d.tenant)
        budget = dag_budget_slices(d, fleet.router.budget(d.slo_class), t)
        g["miss"] += (d.latency_ns / T) > budget
    for d in res.rejected:
        groups.setdefault(d.tenant, {"lat": [], "rej": 0, "unf": 0,
                                     "hand": 0, "miss": 0})["rej"] += 1
    for d in res.unfinished:
        groups.setdefault(d.tenant, {"lat": [], "rej": 0, "unf": 0,
                                     "hand": 0, "miss": 0})["unf"] += 1
    for name, g in sorted(groups.items()):
        lat_ms = [x / 1e6 for x in g["lat"]]
        n = len(lat_ms) + g["rej"] + g["unf"]
        misses = g["miss"] + g["rej"] + g["unf"]
        t = fleet.tenants.get(name)
        out[name] = {
            "slo_class": t.slo_class,
            "dag": t.dag,
            "n_submitted": n,
            "n_completed": len(lat_ms),
            "n_rejected": g["rej"],
            "n_unfinished": g["unf"],
            "deadline_miss_rate": misses / n if n else 0.0,
            "p95_ms": (float(np.percentile(lat_ms, 95)) if lat_ms
                       else 0.0),
            "mean_ms": float(np.mean(lat_ms)) if lat_ms else 0.0,
            "handoffs": g["hand"],
        }
    return out


# -- the fleet ---------------------------------------------------------------


class DagFleet(HierarchicalFleet):
    """A hierarchical fleet that also co-schedules DAG stages.

    Inherits the cells, the two-level router, budgets and
    :meth:`~repro.fleet.hierarchy.HierarchicalFleet.run` (plain traces
    keep working), and adds :meth:`run_dag`: per slice, completed stage
    chunks advance their DAGs' topological frontiers, newly ready
    stages are placed by the :class:`DagCoScheduler`, new DAG arrivals
    pass per-tenant wait-based admission (SS.9 reason codes with a
    ``tenant`` label), and an optional plain background trace shares
    the same cells. Every tenant's SLO class must be registered in the
    router budgets - an unregistered class raises a shaped error at
    construction."""

    def __init__(self, cells: Sequence[Cell], *,
                 tenants: Optional[TenantRegistry] = None,
                 stage_affinity: bool = True,
                 handoff_tax_slices: float = 0.25,
                 handoff_energy_pj: float = 2e5,
                 affinity_bonus: float = 0.1,
                 **hier_kw):
        super().__init__(cells, **hier_kw)
        self.tenants = tenants if tenants is not None else default_tenants()
        for t in self.tenants:
            if t.slo_class not in self.router.budgets:
                raise _unknown(
                    f"SLO class (tenant {t.name!r})", t.slo_class,
                    self.router.budgets)
        self.cosched = DagCoScheduler(
            self.cells, tokens_per_task=self.tokens_per_request,
            handoff_tax_slices=handoff_tax_slices,
            handoff_energy_pj=handoff_energy_pj,
            energy_weight=self.router.energy_weight,
            affinity_bonus=affinity_bonus, stage_affinity=stage_affinity)
        self._dag_rid = itertools.count()

    # -- stage dispatch ------------------------------------------------------
    def _place_stage(self, dag: DagRequest, stage_name: str,
                     slice_idx: int,
                     assignments: List[Tuple[int, str, int, int]]) -> None:
        _obs = obs.enabled()
        _t0 = obs.now_ns() if _obs else 0
        spec = dag.spec.stage(stage_name)
        budget = self.router.budget(dag.slo_class)
        cell = self.cosched.choose(dag, stage_name, budget)
        n_chunks = self.cosched.n_chunks(spec)
        crossings = sum(dag.cell_of[p] != cell.cid
                        for p in dag.spec.parents(stage_name)
                        if p in dag.cell_of)
        dag.handoffs += crossings
        if crossings and _obs:
            obs.counter("dag.handoff", crossings, tenant=dag.tenant)
            obs.instant("dag.handoff", cat="dag", args={
                "dag": dag.rid, "stage": stage_name, "tenant": dag.tenant,
                "to_cell": cell.cid, "crossings": crossings,
                "tax_slices": self.cosched.handoff_tax_slices})
        left = spec.tokens
        for k in range(n_chunks):
            tok = min(self.cosched.tokens_per_task, left)
            left -= tok
            req = StageRequest(
                rid=next(self._rid), arrival_slice=slice_idx, tokens=tok,
                slo_class=dag.slo_class, tenant=dag.tenant,
                dag_rid=dag.rid, stage=stage_name, chunk=k,
                n_chunks=n_chunks)
            req.admission = ADMIT_ACCEPT
            cell.dispatch(req, self.router.cell_policy)
        dag.state[stage_name] = QUEUED
        dag.cell_of[stage_name] = cell.cid
        dag.queued_slice[stage_name] = slice_idx
        dag.chunks_left[stage_name] = n_chunks
        assignments.append((dag.rid, stage_name, cell.cid, slice_idx))
        if _obs:
            obs.complete("dag.stage", _t0, cat="dag", args={
                "dag": dag.rid, "stage": stage_name, "tenant": dag.tenant,
                "cell": cell.cid, "chunks": n_chunks,
                "tokens": spec.tokens, "crossings": crossings})

    def _admit_dag(self, tenant: Tenant, slice_idx: int) -> DagRequest:
        """Per-tenant wait-based admission of a new DAG: the root
        stage's best cell must fit the tenant's (weighted) budget."""
        spec = make_dag_spec(tenant.dag)
        dag = DagRequest(rid=next(self._dag_rid), tenant=tenant.name,
                         slo_class=tenant.slo_class, spec=spec,
                         arrival_slice=slice_idx)
        budget = self.router.budget(tenant.slo_class)
        if tenant.budget_slices is not None:
            budget = tenant.budget_slices
        root = spec.roots()[0]
        best = self.cosched._scores(spec.stage(root), budget, ())[0]
        limit = budget * self.router.admit_headroom * tenant.weight
        admitted = best[1] <= limit
        decision = ADMIT_ACCEPT if admitted else ADMIT_REJECT
        reason = "ok" if admitted else REASON_TENANT_BUDGET
        if obs.enabled():
            obs.counter("fleet.admission", decision=decision,
                        reason=reason, cls=tenant.slo_class,
                        tenant=tenant.name)
            if not admitted:
                obs.instant("fleet.reject", cat="fleet", args={
                    "dag": dag.rid, "tenant": tenant.name,
                    "reason": reason, "budget": budget})
        dag.rejected = not admitted
        return dag

    # -- completion bookkeeping ----------------------------------------------
    def _finish_chunk(self, req: StageRequest,
                      dags: Dict[int, DagRequest]) -> Optional[DagRequest]:
        """Record a completed chunk; returns the DAG when the chunk
        finished its stage (caller advances the frontier)."""
        dag = dags[req.dag_rid]
        T = self.cells[0].t_slice_ns
        abs_ns = req.arrival_slice * T + req.latency_ns
        prev = dag.finish_ns_of.get(req.stage, 0.0)
        dag.finish_ns_of[req.stage] = max(prev, abs_ns)
        dag.chunks_left[req.stage] -= 1
        if dag.chunks_left[req.stage] > 0:
            return None
        dag.state[req.stage] = DONE
        dag.finish_slice_of[req.stage] = req.finish_slice
        if obs.enabled():
            obs.counter("dag.stage.done", tenant=dag.tenant,
                        stage=req.stage)
        return dag

    def _finalize_dag(self, dag: DagRequest, slice_idx: int) -> None:
        T = self.cells[0].t_slice_ns
        dag.finish_slice = slice_idx
        last = max(dag.finish_ns_of.values())
        tax = dag.handoffs * self.cosched.handoff_tax_slices * T
        dag.latency_ns = (last - dag.arrival_slice * T) + tax
        if obs.enabled():
            obs.counter("dag.request.done", tenant=dag.tenant)

    def _record_dag_frame(self, recorder, s: int, arrivals: List[str],
                          done_dags: int, rejected_now: Dict[str, int],
                          trace_name: str, lat_ms: List[float],
                          n_miss: int, n_known: int) -> None:
        """Flight frame for a DAG slice: SS.9 cell aggregates plus
        per-tenant attribution (the breach-dump satellite)."""
        by_tenant: Dict[str, Dict[str, int]] = {}
        for t in arrivals:
            by_tenant.setdefault(t, {"arrivals": 0, "rejected": 0})
            by_tenant[t]["arrivals"] += 1
        for t, n in rejected_now.items():
            by_tenant.setdefault(t, {"arrivals": 0, "rejected": 0})
            by_tenant[t]["rejected"] += n
        miss_rate = (n_miss / n_known) if n_known else 0.0
        p99 = (float(np.percentile(lat_ms, 99)) if lat_ms else None)
        recorder.record(s, {
            "arrivals": len(arrivals),
            "rejected": sum(rejected_now.values()),
            "completed_dags": done_dags,
            "tenants": by_tenant,
            "cells": self._cell_states(),
            "running": {"deadline_miss_rate": round(miss_rate, 4),
                        "p99_ms": p99},
        })
        recorder.check(deadline_miss_rate=miss_rate, p99_ms=p99,
                       context={"trace": trace_name, "slice": s,
                                "dag": True})

    # -- the loop ------------------------------------------------------------
    def run_dag(self, dag_tr: DagTrace, *,
                background: Optional[Trace] = None,
                max_drain_slices: int = 200,
                verbose_cb=None) -> DagResult:
        rng = np.random.default_rng(self.seed)
        dags: Dict[int, DagRequest] = {}
        completed: List[DagRequest] = []
        rejected: List[DagRequest] = []
        stage_done: List[FleetRequest] = []
        bg_done: List[FleetRequest] = []
        bg_rejected: List[FleetRequest] = []
        assignments: List[Tuple[int, str, int, int]] = []
        recorder = obs.flight_recorder()
        if obs.enabled():
            for c in self.cells:
                obs.tracer().name_track(c.cid, f"cell-{c.cid}")
            obs.instant("fleet.run", cat="fleet", args={
                "trace": dag_tr.name, "cells": len(self.cells),
                "engines": self.n_engines, "dag": True,
                "tenants": self.tenants.names(),
                "stage_affinity": self.cosched.stage_affinity})
        T = self.cells[0].t_slice_ns
        lat_ms: List[float] = []
        n_miss = 0
        n_known = 0                   # dags with a final outcome so far
        s = 0
        n_slices = len(dag_tr)
        bg_arr = background.arrivals if background is not None else []
        while True:
            draining = s >= n_slices
            active = [d for d in dags.values()
                      if not d.rejected and not d.done]
            if draining and ((not active
                              and all(c.backlog == 0 for c in self.cells))
                             or s >= n_slices + max_drain_slices):
                break
            _obs = obs.enabled()
            _t0 = obs.now_ns() if _obs else 0
            self.router.refresh()
            # 1) execute backlog; completed chunks advance their DAGs
            done_now: List[FleetRequest] = []
            for c in self.cells:
                done_now.extend(c.step(s, self.router.budget))
            ready: List[Tuple[DagRequest, str]] = []
            seen_ready: set = set()
            done_dags = 0
            for r in done_now:
                if isinstance(r, StageRequest):
                    stage_done.append(r)
                    dag = self._finish_chunk(r, dags)
                    if dag is None:
                        continue
                    if dag.done:
                        self._finalize_dag(dag, s)
                        completed.append(dag)
                        done_dags += 1
                        budget = dag_budget_slices(
                            dag, self.router.budget(dag.slo_class),
                            self.tenants.get(dag.tenant))
                        lat_ms.append(dag.latency_ns / 1e6)
                        n_known += 1
                        n_miss += (dag.latency_ns / T) > budget
                    else:
                        # two parents finishing in one slice both see the
                        # child as ready: place it once
                        for nm in dag.ready_stages():
                            if (dag.rid, nm) not in seen_ready:
                                seen_ready.add((dag.rid, nm))
                                ready.append((dag, nm))
                else:
                    bg_done.append(r)
            # 2) new DAG arrivals (per-tenant wait-based admission)
            arrivals = dag_tr.arrivals[s] if not draining else []
            rejected_now: Dict[str, int] = {}
            for tname in arrivals:
                tenant = self.tenants.get(tname)
                dag = self._admit_dag(tenant, s)
                dags[dag.rid] = dag
                if dag.rejected:
                    rejected.append(dag)
                    rejected_now[tname] = rejected_now.get(tname, 0) + 1
                    n_known += 1
                    n_miss += 1
                    continue
                for nm in dag.ready_stages():
                    ready.append((dag, nm))
            # 3) place the ready frontier (deterministic order)
            ready.sort(key=lambda t: (t[0].rid,
                                      t[0].spec.topo_order().index(t[1])))
            for dag, nm in ready:
                self._place_stage(dag, nm, s, assignments)
            # 4) plain background arrivals share the same cells
            n_bg = bg_arr[s] if (not draining and s < len(bg_arr)) else 0
            for _ in range(n_bg):
                cls = (self._classes[0] if len(self._classes) == 1 else
                       self._classes[int(rng.choice(len(self._classes),
                                                    p=self._probs))])
                req = FleetRequest(rid=next(self._rid), arrival_slice=s,
                                   tokens=self.tokens_per_request,
                                   slo_class=cls)
                if not self.router.route(req):
                    bg_rejected.append(req)
            if self.autoscaler is not None and not draining:
                self.autoscaler.observe(s, self.cells)
            for c in self.cells:
                c.end_of_slice()
            if _obs:
                obs.complete("fleet.slice", _t0, cat="fleet", args={
                    "slice": s, "dag_arrivals": len(arrivals),
                    "stages_placed": len(ready),
                    "chunks_done": len(done_now),
                    "dags_done": done_dags,
                    "backlog": sum(c.backlog for c in self.cells)})
            if recorder is not None:
                self._record_dag_frame(
                    recorder, s, arrivals, done_dags, rejected_now,
                    dag_tr.name, lat_ms, n_miss, n_known)
            if verbose_cb is not None:
                verbose_cb(s, arrivals, done_dags, self.cells)
            s += 1
        unfinished = [d for d in dags.values()
                      if not d.rejected and not d.done]
        workers = self.workers
        leftover = [r for w in workers for r in w.backlog]
        stage_result = FleetResult(
            trace=dag_tr.name, completed=stage_done, rejected=[],
            unfinished=[r for r in leftover
                        if isinstance(r, StageRequest)],
            reports={w.wid: w.reports for w in workers},
            t_slice_ns=T, slo_ns=self.slo_slices * T, n_slices=s)
        bg_result = None
        if background is not None:
            bg_result = FleetResult(
                trace=background.name, completed=bg_done,
                rejected=bg_rejected,
                unfinished=[r for r in leftover
                            if not isinstance(r, StageRequest)],
                reports={}, t_slice_ns=T,
                slo_ns=self.slo_slices * T, n_slices=s)
        if recorder is not None:
            n_sub = n_known + len(unfinished)
            recorder.check(
                deadline_miss_rate=((n_miss + len(unfinished)) / n_sub
                                    if n_sub else 0.0),
                p99_ms=(float(np.percentile(lat_ms, 99)) if lat_ms
                        else None),
                context={"trace": dag_tr.name, "phase": "end_of_run",
                         "dag": True, "n_slices": s})
        return DagResult(
            trace=dag_tr.name, completed=completed, rejected=rejected,
            unfinished=unfinished, stage_result=stage_result,
            assignments=assignments,
            handoffs=sum(d.handoffs for d in dags.values()),
            handoff_energy_pj=(sum(d.handoffs for d in dags.values())
                               * self.cosched.handoff_energy_pj),
            background_result=bg_result)
