"""Per-slice load forecasters.

The paper's scheduler is *reactive*: slice ``s`` executes the backlog that
arrived during ``s-1``, and the LUT is consulted on that realized count. A
forecaster predicts the NEXT slice's arrivals from the arrival history, and
the fleet worker looks the LUT up on ``max(backlog, prediction)`` - so a
predicted burst triggers the weight migration one slice early, while the
engine is still quiet enough to absorb the movement overhead
(``TimeSliceScheduler.step(lookup_tasks=...)``).

All forecasters are O(1) memory/time per observation (online updates).
"""
from __future__ import annotations

import collections
from typing import Callable, Deque, Dict, Optional


class Forecaster:
    """Online one-step-ahead predictor of per-slice arrival counts."""

    name = "base"

    def observe(self, n_arrivals: int) -> None:
        raise NotImplementedError

    def predict(self) -> float:
        """Predicted arrivals in the next slice (>= 0)."""
        raise NotImplementedError


class NoForecast(Forecaster):
    """Reactive baseline: predicts nothing; the LUT sees the raw backlog."""

    name = "none"

    def observe(self, n_arrivals: int) -> None:
        pass

    def predict(self) -> float:
        return 0.0


class LastValue(Forecaster):
    """Naive persistence: next slice repeats the last observation."""

    name = "last"

    def __init__(self) -> None:
        self._last = 0.0

    def observe(self, n_arrivals: int) -> None:
        self._last = float(n_arrivals)

    def predict(self) -> float:
        return self._last


class EWMA(Forecaster):
    """Exponentially weighted moving average of arrivals.

    Smooths transient dips, so an engine serving a sustained burst does not
    migrate down during a one-slice lull only to migrate back up (migration
    thrash is the dominant reactive failure mode on MMPP traffic)."""

    name = "ewma"

    def __init__(self, alpha: float = 0.5) -> None:
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.alpha = alpha
        self._level: Optional[float] = None

    def observe(self, n_arrivals: int) -> None:
        x = float(n_arrivals)
        self._level = x if self._level is None else \
            self.alpha * x + (1 - self.alpha) * self._level

    def predict(self) -> float:
        return self._level or 0.0


class AR1(Forecaster):
    """Online AR(1): ``x_{t+1} ~ mu + phi (x_t - mu)``.

    ``mu`` and ``phi`` are estimated from running first/second moments of
    consecutive pairs; ``phi`` is clipped to [0, 1] (arrival counts are
    non-negatively autocorrelated in every traffic model we generate)."""

    name = "ar1"

    def __init__(self, min_obs: int = 3) -> None:
        self.min_obs = min_obs
        self._prev: Optional[float] = None
        self._n = 0
        self._sx = self._sxx = self._sxy = 0.0
        self._last = 0.0

    def observe(self, n_arrivals: int) -> None:
        x = float(n_arrivals)
        if self._prev is not None:
            self._n += 1
            self._sx += self._prev
            self._sxx += self._prev * self._prev
            self._sxy += self._prev * x
        self._prev = x
        self._last = x

    def predict(self) -> float:
        if self._n < self.min_obs:
            return self._last
        mu = (self._sx + self._last) / (self._n + 1)
        var = self._sxx / self._n - (self._sx / self._n) ** 2
        if var <= 1e-9:
            return self._last
        cov = self._sxy / self._n - (self._sx / self._n) * mu
        phi = min(max(cov / var, 0.0), 1.0)
        return max(mu + phi * (self._last - mu), 0.0)


class Holt(Forecaster):
    """Double-exponential (level + trend) smoothing: extrapolates ramps, so
    rising load is pre-provisioned a slice early."""

    name = "holt"

    def __init__(self, alpha: float = 0.5, beta: float = 0.3) -> None:
        self.alpha, self.beta = alpha, beta
        self._level: Optional[float] = None
        self._trend = 0.0

    def observe(self, n_arrivals: int) -> None:
        x = float(n_arrivals)
        if self._level is None:
            self._level = x
            return
        prev = self._level
        self._level = self.alpha * x + (1 - self.alpha) * (prev + self._trend)
        self._trend = (self.beta * (self._level - prev)
                       + (1 - self.beta) * self._trend)

    def predict(self) -> float:
        if self._level is None:
            return 0.0
        return max(self._level + self._trend, 0.0)


class SeasonalNaive(Forecaster):
    """Period-aware persistence: predicts the observation from one period
    ago (nails the paper's periodic-spike cases, where every history-free
    smoother lags the spike by construction)."""

    name = "seasonal"

    def __init__(self, period: int = 10) -> None:
        if period < 1:
            raise ValueError("period must be >= 1")
        self.period = period
        self._hist: Deque[float] = collections.deque(maxlen=period)

    def observe(self, n_arrivals: int) -> None:
        self._hist.append(float(n_arrivals))

    def predict(self) -> float:
        if len(self._hist) < self.period:
            return self._hist[-1] if self._hist else 0.0
        return self._hist[0]


FORECASTERS: Dict[str, Callable[..., Forecaster]] = {
    "none": NoForecast,
    "last": LastValue,
    "ewma": EWMA,
    "ar1": AR1,
    "holt": Holt,
    "seasonal": SeasonalNaive,
}


def make_forecaster(name: str, **kw) -> Forecaster:
    if name not in FORECASTERS:
        raise ValueError(f"unknown forecaster {name!r}; "
                         f"choose from {sorted(FORECASTERS)}")
    return FORECASTERS[name](**kw)
