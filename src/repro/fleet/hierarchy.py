"""Two-level (cell -> engine) fleet: hierarchical routing + autoscaling.

The flat :class:`~repro.fleet.router.FleetRouter` scores every engine for
every arrival - O(requests x engines log engines) - and tops out at a
handful of engines. This module scales the serving loop to hundreds ->
thousands of simulated engines by introducing the **cell** as the unit of
placement (after the heterogeneous data-centric survey, PAPERS.md): a
cell groups engines of ONE substrate variant behind an aggregate queue
model, the HH-PIM energy/latency trade (Eq. (1), DESIGN.md SS.3) is
decided per cell, and routing becomes two cheap decisions:

* **global tier** (:class:`CellRouter`): pick a cell by queue-aware
  scoring - expected queue wait (aggregate backlog over aggregate
  capacity, bias-corrected by an EWMA of realized waits from the same
  per-class queue-wait signal the PR 6 ``fleet.queue_wait_slices``
  histograms record) as a fraction of the request class's SLO budget,
  plus a small energy/token term from the cell's LUT-backed placement.
  Admission control is wait-based per class: a request is admitted only
  into a cell whose expected completion latency fits its class budget,
  and the PR 6 admission reason codes (``accept`` / ``defer`` /
  ``reject``) are stamped + counted exactly as in the flat router.
* **cell tier** (:meth:`Cell.dispatch`): pick an engine inside the
  chosen cell - least-loaded or join-shortest-queue.

:class:`CellAutoscaler` brings engine pools up/down per cell from
queue-depth and miss-rate signals with hysteresis (watermarks +
patience + cooldown). Scale-ups first unpark previously parked engines
and otherwise build new workers through the fleet's shared
:class:`~repro.core.compiler.PlacementCompiler` - the variant's LUT was
compiled at bring-up (or loaded via ``save()``/``load()`` warm start),
so a scale-up costs **zero** LUT builds; every :class:`ScaleEvent`
records the builds it actually paid so benches and CI can assert that.

Construct through :func:`repro.api.hierarchical_fleet`; the run loop,
latency accounting and result schema match :class:`~repro.fleet.router.
Fleet` so :func:`repro.fleet.metrics.summarize` applies unchanged.
See DESIGN.md SS.9.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.fleet.router import (ADMIT_ACCEPT, ADMIT_DEFER, ADMIT_REJECT,
                                EngineWorker, FleetRequest, FleetResult,
                                _nearest_rank)
from repro.fleet.traces import Trace

CELL_POLICIES = ("least_loaded", "jsq")

#: admission reject reason emitted by the wait-based global tier
#: (complements the flat router's "all_queues_full"; DESIGN.md SS.8/SS.9)
REASON_BUDGET = "slo_budget_exhausted"

#: EWMA weight of the realized-vs-predicted wait correction
_BIAS_ALPHA = 0.2
#: slices of completion history feeding the autoscaler miss signal
_MISS_WINDOW = 8


class Cell:
    """Engines of one substrate variant behind an aggregate queue model.

    The cell maintains an incrementally-updated aggregate backlog and a
    once-per-slice capacity estimate, so the global tier scores a cell in
    O(1) instead of touching its engines. Realized queue waits feed an
    EWMA bias correction (``_wait_bias``) and a per-cell wait histogram
    on the PR 6 ``WAIT_SLICE_BUCKETS`` grid.
    """

    def __init__(self, cid: int, workers: Sequence[EngineWorker], *,
                 substrate=None, tokens_per_task: int = 8):
        if not workers:
            raise ValueError(f"cell {cid} needs at least one engine")
        self.cid = cid
        self.workers = list(workers)          # active engines
        self.parked: List[EngineWorker] = []  # scaled-down, warm
        self.substrate = substrate
        self.tokens_per_task = tokens_per_task
        self.backlog = 0                      # aggregate queued tasks
        self.wait_hist = obs.Histogram(obs.WAIT_SLICE_BUCKETS)
        self._wait_bias = 0.0
        self._cap_engine = 1.0                # tasks/slice of one engine
        self._energy_norm = 0.0               # set by CellRouter.refresh
        # (n_done, n_missed_budget) per recent slice -> miss signal
        self._recent: collections.deque = collections.deque(
            maxlen=_MISS_WINDOW)
        self.refresh()

    # -- aggregate queue model ---------------------------------------------
    @property
    def n_active(self) -> int:
        return len(self.workers)

    @property
    def t_slice_ns(self) -> float:
        return self.workers[0].t_slice_ns

    def refresh(self) -> None:
        """Once per slice: re-estimate per-engine capacity and the
        energy/token of the cell's current placement (both move only
        when a placement changes)."""
        T = self.t_slice_ns
        ts = [w.t_task_est_ns() for w in self.workers]
        mean_t = sum(ts) / len(ts)
        self._cap_engine = T / mean_t if mean_t > 0 else float("inf")
        em = self.workers[0].sched.em
        cost = em.task_cost(self.workers[0].sched.placement)
        self.energy_per_token_pj = (cost.e_dyn_task_pj
                                    / max(self.tokens_per_task, 1))

    def expected_wait_slices(self, extra: int = 1) -> float:
        """Slices a newly admitted request expects to queue, from the
        aggregate backlog spread over the active engines, corrected by
        the EWMA of realized-minus-predicted waits."""
        if not math.isfinite(self._cap_engine):
            return self._wait_bias
        per_engine = (self.backlog + extra) / self.n_active
        return max(per_engine / self._cap_engine + self._wait_bias, 0.0)

    def expected_latency_slices(self, extra: int = 1) -> float:
        """Expected completion latency in slices: arrivals buffer one
        slice before executing (the paper's <= 2T discipline), then wait
        out the queue ahead of them."""
        return 1.0 + self.expected_wait_slices(extra)

    def recent_miss_rate(self) -> float:
        done = sum(d for d, _ in self._recent)
        missed = sum(m for _, m in self._recent)
        return missed / done if done else 0.0

    # -- cell tier: engine selection ---------------------------------------
    def dispatch(self, req: FleetRequest, policy: str = "least_loaded"
                 ) -> None:
        """Second routing tier: enqueue on the least-loaded (queue
        length) or shortest-expected-wait (jsq) engine of this cell."""
        if policy == "jsq":
            w = min(self.workers,
                    key=lambda w: (w.expected_wait_slices(1), w.wid))
        else:
            w = min(self.workers, key=lambda w: (len(w.backlog), w.wid))
        req.cell = self.cid
        w.enqueue(req)
        self.backlog += 1

    # -- per-slice protocol ------------------------------------------------
    def step(self, slice_idx: int, budget_slices: Callable[[str], float]
             ) -> List[FleetRequest]:
        _obs = obs.enabled()
        _t0 = obs.now_ns() if _obs else 0
        done: List[FleetRequest] = []
        for w in self.workers:
            done.extend(w.step(slice_idx))
        self.backlog = sum(len(w.backlog) for w in self.workers)
        n_missed = 0
        for r in done:
            wait = r.finish_slice - r.arrival_slice - 1
            self.wait_hist.observe(wait)
            if r.wait_est is not None:
                self._wait_bias += _BIAS_ALPHA * (
                    wait - r.wait_est - self._wait_bias)
            lat_slices = r.latency_ns / self.t_slice_ns
            n_missed += lat_slices > budget_slices(r.slo_class)
        self._recent.append((len(done), n_missed))
        if _obs:
            obs.complete("cell.step", _t0, cat="fleet", tid=self.cid,
                         args={"cell": self.cid, "engines": self.n_active,
                               "backlog": self.backlog,
                               "n_done": len(done)})
        return done

    def end_of_slice(self) -> None:
        for w in self.workers:
            w.end_of_slice()

    # -- scaling hooks (CellAutoscaler) ------------------------------------
    def park_one(self) -> bool:
        """Scale down by one engine: park the emptiest ACTIVE engine.
        Only engines with a drained backlog park (no request stranding);
        returns False when none qualifies or one engine would remain."""
        if self.n_active <= 1:
            return False
        idle = [w for w in self.workers if not w.backlog]
        if not idle:
            return False
        w = min(idle, key=lambda w: -w.wid)    # newest engine first
        self.workers.remove(w)
        self.parked.append(w)
        return True

    def unpark_one(self) -> bool:
        if not self.parked:
            return False
        self.workers.append(self.parked.pop())
        return True

    def add_worker(self, w: EngineWorker) -> None:
        self.workers.append(w)

    def all_workers(self) -> List[EngineWorker]:
        return self.workers + self.parked


class CellRouter:
    """Global routing tier: queue-aware cell scoring with per-class SLO
    budgets and wait-based admission.

    Score = (expected completion latency / class budget)
          + ``energy_weight`` x (cell energy/token, min-max normalized
            across cells each slice). The request is admitted into the
    best-scoring cell whose expected latency fits its class budget
    (times ``admit_headroom``); if the top-scoring cell does not fit but
    a later one does, the outcome is ``defer`` (reason
    ``preferred_over_budget``); if none fits, ``reject`` (reason
    ``slo_budget_exhausted``). Admission outcomes reuse the flat
    router's PR 6 reason-code schema (DESIGN.md SS.8)."""

    def __init__(self, cells: Sequence[Cell], *,
                 budgets: Optional[Dict[str, float]] = None,
                 slo_slices: float = 2.0,
                 energy_weight: float = 0.05,
                 admit_headroom: float = 1.0,
                 cell_policy: str = "least_loaded"):
        if not cells:
            raise ValueError("router needs at least one cell")
        if cell_policy not in CELL_POLICIES:
            raise ValueError(f"unknown cell policy {cell_policy!r}; "
                             f"one of {CELL_POLICIES}")
        self.cells = list(cells)
        self.budgets = dict(budgets or {})
        self.budgets.setdefault("default", slo_slices)
        self.energy_weight = energy_weight
        self.admit_headroom = admit_headroom
        self.cell_policy = cell_policy

    def budget(self, slo_class: str) -> float:
        """SLO budget of a class, in slices. Unknown classes raise a
        shaped error naming the class and listing the registered set -
        classes are registered via ``budgets=`` (or inherited from
        ``class_mix=`` at fleet construction); there is no silent
        default fallback."""
        try:
            return self.budgets[slo_class]
        except KeyError:
            raise ValueError(
                f"unknown SLO class {slo_class!r}; registered: "
                f"{sorted(self.budgets)} (register it via budgets= or "
                f"class_mix=)") from None

    def refresh(self) -> None:
        """Once per slice: refresh every cell's capacity/energy estimate
        and min-max normalize energy/token across cells (the relative
        term the score uses; degenerate spread -> 0 for all)."""
        for c in self.cells:
            c.refresh()
        es = [c.energy_per_token_pj for c in self.cells]
        lo, hi = min(es), max(es)
        spread = hi - lo
        for c in self.cells:
            c._energy_norm = ((c.energy_per_token_pj - lo) / spread
                              if spread > 0 else 0.0)

    def route(self, req: FleetRequest) -> bool:
        """Two-level dispatch; False => rejected by wait-based admission.
        Backlogs update as requests enqueue, so scores stay fresh within
        a slice."""
        b = self.budget(req.slo_class)
        scored = sorted(
            ((c.expected_latency_slices(1) / b
              + self.energy_weight * c._energy_norm,
              c.expected_latency_slices(1), c) for c in self.cells),
            key=lambda t: (t[0], t[2].cid))
        limit = b * self.admit_headroom
        for rank, (_, lat, c) in enumerate(scored):
            if lat <= limit:
                req.admission = ADMIT_ACCEPT if rank == 0 else ADMIT_DEFER
                req.wait_est = lat - 1.0
                if obs.enabled():
                    reason = ("ok" if rank == 0 else "preferred_over_budget")
                    obs.counter("fleet.admission", decision=req.admission,
                                reason=reason, cls=req.slo_class,
                                tenant=req.tenant)
                    obs.counter("cell.dispatch", cell=c.cid)
                c.dispatch(req, self.cell_policy)
                return True
        req.rejected = True
        req.admission = ADMIT_REJECT
        if obs.enabled():
            obs.counter("fleet.admission", decision=ADMIT_REJECT,
                        reason=REASON_BUDGET, cls=req.slo_class,
                        tenant=req.tenant)
            obs.instant("fleet.reject", cat="fleet",
                        args={"rid": req.rid, "reason": REASON_BUDGET,
                              "cls": req.slo_class, "tenant": req.tenant,
                              "budget": b})
        return False


@dataclasses.dataclass
class ScaleEvent:
    """One autoscaler action: the LUT builds the event paid is the
    warm-start audit trail (scale-ups must report 0)."""
    slice_idx: int
    cell: int
    direction: str                # "up" | "down"
    n_engines: int                # active engines AFTER the event
    lut_builds: int = 0
    unparked: bool = False        # reused a parked engine (no new build)


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    """Hysteresis state machine knobs (DESIGN.md SS.9): a cell scales up
    after ``patience`` consecutive slices above ``up_wait`` expected
    queue wait (or above ``up_miss`` recent budget-miss rate), scales
    down after ``patience`` consecutive slices below ``down_wait`` with
    an idle engine to park, and after any event ignores both signals for
    ``cooldown`` slices. ``up_wait > down_wait`` + patience + cooldown
    is what prevents flapping on a step load."""
    min_engines: int = 1
    max_engines: int = 8
    # wait-based admission clamps a saturated cell's expected wait near
    # (budget - 1) slices, so the high watermark sits BELOW 1.0: a cell
    # pinned at its admission ceiling reads as hot, not as healthy
    up_wait: float = 0.75         # slices; scale-up high watermark
    down_wait: float = 0.15       # slices; scale-down low watermark
    up_miss: float = 0.25         # recent budget-miss-rate trigger
    patience: int = 2             # consecutive slices before acting
    cooldown: int = 2             # slices to hold after an event


class CellAutoscaler:
    """Per-cell engine-pool scaling from queue-depth + miss signals.

    ``worker_factory(cell)`` builds one new :class:`EngineWorker` for a
    cell through the fleet's shared placement compiler; the autoscaler
    measures the compiler builds each scale-up actually paid (0 when the
    variant's LUT is warm) and records them on the :class:`ScaleEvent`.
    """

    def __init__(self, cfg: AutoscaleConfig,
                 worker_factory: Callable[[Cell], EngineWorker],
                 compiler=None):
        self.cfg = cfg
        self.worker_factory = worker_factory
        self.compiler = compiler
        self._hot: Dict[int, int] = {}       # cid -> consecutive hot slices
        self._cold: Dict[int, int] = {}
        self._hold: Dict[int, int] = {}      # cid -> cooldown remaining
        self.events: List[ScaleEvent] = []

    def _builds(self) -> int:
        return self.compiler.n_builds if self.compiler is not None else 0

    def _scale_up(self, slice_idx: int, cell: Cell) -> ScaleEvent:
        b0 = self._builds()
        unparked = cell.unpark_one()
        if not unparked:
            w = self.worker_factory(cell)
            w.sched.lut          # force the LUT now: builds land on event
            cell.add_worker(w)
        ev = ScaleEvent(slice_idx=slice_idx, cell=cell.cid, direction="up",
                        n_engines=cell.n_active,
                        lut_builds=self._builds() - b0, unparked=unparked)
        return ev

    def observe(self, slice_idx: int, cells: Sequence[Cell]
                ) -> List[ScaleEvent]:
        """Run one autoscaling round over the cells; returns the events
        applied this slice (new engines serve from the next slice)."""
        fired: List[ScaleEvent] = []
        cfg = self.cfg
        for cell in cells:
            cid = cell.cid
            if self._hold.get(cid, 0) > 0:
                self._hold[cid] -= 1
                continue
            wait = cell.expected_wait_slices(0)
            hot = wait > cfg.up_wait or cell.recent_miss_rate() > cfg.up_miss
            cold = wait < cfg.down_wait
            self._hot[cid] = self._hot.get(cid, 0) + 1 if hot else 0
            self._cold[cid] = self._cold.get(cid, 0) + 1 if cold else 0
            ev = None
            if (self._hot[cid] >= cfg.patience
                    and cell.n_active < cfg.max_engines):
                ev = self._scale_up(slice_idx, cell)
            elif (self._cold[cid] >= cfg.patience
                    and cell.n_active > cfg.min_engines
                    and cell.park_one()):
                ev = ScaleEvent(slice_idx=slice_idx, cell=cid,
                                direction="down", n_engines=cell.n_active)
            if ev is not None:
                fired.append(ev)
                self.events.append(ev)
                self._hot[cid] = self._cold[cid] = 0
                self._hold[cid] = cfg.cooldown
                obs.metrics().counter("fleet.autoscale",
                                      direction=ev.direction)
                if obs.enabled():
                    obs.instant("fleet.scale", cat="fleet",
                                args=dataclasses.asdict(ev))
        return fired


@dataclasses.dataclass
class HierarchyResult:
    """A :class:`~repro.fleet.router.FleetResult` (so ``summarize()``
    applies unchanged) plus the hierarchy's own audit trail."""
    result: FleetResult
    scale_events: List[ScaleEvent]
    n_engines_start: int
    n_engines_peak: int
    n_engines_end: int
    #: (rid, cell, wid) per admitted request, in admission order - the
    #: determinism contract: same trace + seed => identical sequence
    assignments: List[Tuple[int, int, int]]

    @property
    def scale_up_builds(self) -> int:
        return sum(e.lut_builds for e in self.scale_events
                   if e.direction == "up")

    @property
    def n_scale_ups(self) -> int:
        return sum(e.direction == "up" for e in self.scale_events)

    @property
    def n_scale_downs(self) -> int:
        return sum(e.direction == "down" for e in self.scale_events)


class HierarchicalFleet:
    """Trace-driven two-level serving loop over cells of engines.

    Mirrors :meth:`repro.fleet.router.Fleet.run` - same buffering
    discipline, latency stamping, drain semantics and flight-recorder
    triggers - with per-CELL flight frames (hundreds of engines would
    blow up per-engine frames) and an optional :class:`CellAutoscaler`
    run each slice. ``class_mix`` assigns SLO classes to arrivals from a
    seeded RNG, so runs are deterministic per (trace, seed)."""

    def __init__(self, cells: Sequence[Cell], *,
                 budgets: Optional[Dict[str, float]] = None,
                 class_mix: Optional[Dict[str, float]] = None,
                 slo_slices: float = 2.0,
                 tokens_per_request: int = 8,
                 autoscaler: Optional[CellAutoscaler] = None,
                 cell_policy: str = "least_loaded",
                 energy_weight: float = 0.05,
                 admit_headroom: float = 1.0,
                 seed: int = 0):
        if not cells:
            raise ValueError("hierarchical fleet needs at least one cell")
        self.cells = list(cells)
        self.router = CellRouter(self.cells, budgets=budgets,
                                 slo_slices=slo_slices,
                                 energy_weight=energy_weight,
                                 admit_headroom=admit_headroom,
                                 cell_policy=cell_policy)
        self.slo_slices = slo_slices
        self.tokens_per_request = tokens_per_request
        self.autoscaler = autoscaler
        self.seed = seed
        if class_mix:
            total = sum(class_mix.values())
            self._classes = sorted(class_mix)
            self._probs = [class_mix[c] / total for c in self._classes]
            # classes the mix generates without an explicit budget
            # inherit the default one (budget() itself never falls back)
            for c in self._classes:
                self.router.budgets.setdefault(c, slo_slices)
        else:
            self._classes = ["default"]
            self._probs = [1.0]
        self._rid = itertools.count()

    @property
    def workers(self) -> List[EngineWorker]:
        """Every engine ever part of the fleet (active + parked), in wid
        order - the accounting surface for reports/energy."""
        ws = [w for c in self.cells for w in c.all_workers()]
        return sorted(ws, key=lambda w: w.wid)

    @property
    def n_engines(self) -> int:
        return sum(c.n_active for c in self.cells)

    def _cell_states(self) -> List[Dict]:
        """Per-cell aggregate state for a flight frame (shared by the
        plain and DAG run loops; schema: DESIGN.md SS.9)."""
        return [{
            "cell": c.cid,
            "engines": c.n_active,
            "parked": len(c.parked),
            "queue_depth": c.backlog,
            "expected_wait": round(c.expected_wait_slices(0), 3),
            "capacity_per_engine": round(c._cap_engine, 2),
            "recent_miss_rate": round(c.recent_miss_rate(), 4),
        } for c in self.cells]

    def _record_frame(self, recorder, s: int, n_arr: int, done_n: int,
                      rejected_now: int, scaled: List[ScaleEvent],
                      trace: Trace, lat_ms: List[float], n_miss: int,
                      slo_ms: float) -> None:
        """Flight frame with per-cell aggregates (schema: DESIGN.md SS.9;
        the flat fleet's per-engine form is SS.8)."""
        reg = obs.metrics()
        cells = self._cell_states()
        denom = len(lat_ms) + (n_miss - sum(x > slo_ms for x in lat_ms))
        miss_rate = (n_miss / denom) if denom else 0.0
        recorder.record(s, {
            "arrivals": n_arr,
            "admitted": n_arr - rejected_now,
            "rejected": rejected_now,
            "completed": done_n,
            "cells": cells,
            "scale_events": [dataclasses.asdict(e) for e in scaled],
            "lut_cache": {"builds": reg.value("compiler.lut.build"),
                          "hits": reg.value("compiler.lut.hit")},
            "running": {"deadline_miss_rate": round(miss_rate, 4),
                        "p99_ms": _nearest_rank(lat_ms, 99)},
        })
        recorder.check(deadline_miss_rate=miss_rate,
                       p99_ms=_nearest_rank(lat_ms, 99),
                       context={"trace": trace.name, "slice": s,
                                "slo_ms": slo_ms, "hierarchy": True})

    def run(self, trace: Trace, *, max_drain_slices: int = 200,
            verbose_cb=None) -> HierarchyResult:
        rng = np.random.default_rng(self.seed)
        completed: List[FleetRequest] = []
        rejected: List[FleetRequest] = []
        assignments: List[Tuple[int, int, int]] = []
        n_start = self.n_engines
        n_peak = n_start
        recorder = obs.flight_recorder()
        if obs.enabled():
            for c in self.cells:
                obs.tracer().name_track(c.cid, f"cell-{c.cid}")
            obs.instant("fleet.run", cat="fleet",
                        args={"trace": trace.name, "cells": len(self.cells),
                              "engines": n_start, "hierarchy": True,
                              "autoscale": self.autoscaler is not None})
        slo_ms = self.slo_slices * self.cells[0].t_slice_ns / 1e6
        lat_ms: List[float] = []
        n_miss = 0
        s = 0
        n_slices = len(trace.arrivals)
        while True:
            draining = s >= n_slices
            if draining and (all(c.backlog == 0 for c in self.cells)
                             or s >= n_slices + max_drain_slices):
                break
            _obs = obs.enabled()
            _t0 = obs.now_ns() if _obs else 0
            self.router.refresh()
            # 1) execute backlog buffered from earlier slices
            done_now: List[FleetRequest] = []
            for c in self.cells:
                done_now.extend(c.step(s, self.router.budget))
            completed.extend(done_now)
            # 2) two-level dispatch of this slice's arrivals
            n_arr = trace.arrivals[s] if not draining else 0
            rejected_now = 0
            for _ in range(n_arr):
                cls = (self._classes[0] if len(self._classes) == 1 else
                       self._classes[int(rng.choice(len(self._classes),
                                                    p=self._probs))])
                req = FleetRequest(rid=next(self._rid), arrival_slice=s,
                                   tokens=self.tokens_per_request,
                                   slo_class=cls)
                if self.router.route(req):
                    assignments.append((req.rid, req.cell, req.worker))
                else:
                    rejected.append(req)
                    rejected_now += 1
            # 3) autoscaling acts on post-dispatch queues; new engines
            #    serve from the next slice
            scaled: List[ScaleEvent] = []
            if self.autoscaler is not None and not draining:
                scaled = self.autoscaler.observe(s, self.cells)
                n_peak = max(n_peak, self.n_engines)
            for c in self.cells:
                c.end_of_slice()
            if _obs:
                obs.complete("fleet.slice", _t0, cat="fleet",
                             args={"slice": s, "arrivals": n_arr,
                                   "done": len(done_now),
                                   "rejected": rejected_now,
                                   "engines": self.n_engines,
                                   "backlog": sum(c.backlog
                                                  for c in self.cells)})
            if recorder is not None:
                n_miss += rejected_now
                for r in done_now:
                    lat_ms.append(r.latency_ns / 1e6)
                    n_miss += r.latency_ns / 1e6 > slo_ms
                self._record_frame(recorder, s, n_arr, len(done_now),
                                   rejected_now, scaled, trace, lat_ms,
                                   n_miss, slo_ms)
            if verbose_cb is not None:
                verbose_cb(s, n_arr, done_now, self.cells)
            s += 1
        workers = self.workers
        T = self.cells[0].t_slice_ns
        unfinished = [r for w in workers for r in w.backlog]
        if recorder is not None:
            n_miss += len(unfinished)
            n_sub = len(completed) + len(rejected) + len(unfinished)
            recorder.check(
                deadline_miss_rate=(n_miss / n_sub) if n_sub else 0.0,
                p99_ms=_nearest_rank(lat_ms, 99),
                context={"trace": trace.name, "phase": "end_of_run",
                         "slo_ms": slo_ms, "n_slices": s,
                         "hierarchy": True})
        result = FleetResult(
            trace=trace.name, completed=completed, rejected=rejected,
            unfinished=unfinished,
            reports={w.wid: w.reports for w in workers},
            t_slice_ns=T, slo_ns=self.slo_slices * T, n_slices=s)
        return HierarchyResult(
            result=result,
            scale_events=(self.autoscaler.events
                          if self.autoscaler is not None else []),
            n_engines_start=n_start, n_engines_peak=n_peak,
            n_engines_end=self.n_engines, assignments=assignments)
