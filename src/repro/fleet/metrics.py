"""Fleet-level serving metrics.

Aggregates a :class:`~repro.fleet.router.FleetResult` into the numbers the
paper's evaluation cares about, lifted to fleet scale: tail latency
(p50/p95/p99), energy per decoded token, deadline-miss rate against the
<= 2T operational SLO, and weight-migration counts (placement churn).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.fleet.router import FleetResult


def percentile(xs: Sequence[float], q: float) -> float:
    """NaN on empty input: callers that aggregate decide the fallback
    (``summarize`` maps the no-completions case to 0.0 + ``degenerate``
    instead of letting NaN poison downstream JSON/gates)."""
    if not len(xs):
        return float("nan")
    return float(np.percentile(np.asarray(xs, np.float64), q))


@dataclasses.dataclass
class FleetSummary:
    trace: str
    n_slices: int
    n_engines: int
    n_submitted: int
    n_completed: int
    n_rejected: int
    n_unfinished: int
    p50_ms: float
    p95_ms: float
    p99_ms: float
    mean_ms: float
    slo_ms: float
    deadline_miss_rate: float     # SLO violations (+ rejections) / submitted
    energy_uj: float
    energy_per_token_uj: float
    tokens: int
    migrations: int               # slices where weights actually moved
    weights_moved: int
    mean_backlog: float
    peak_backlog: int
    # no request ever completed: latency stats are 0.0 placeholders, not
    # NaN (NaN breaks JSON round-trips and silently un-gates CI checks)
    degenerate: bool = False

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def class_breakdown(res: FleetResult,
                    budgets: Optional[Dict[str, float]] = None
                    ) -> Dict[str, Dict]:
    """Per-SLO-class outcome stats for a (possibly hierarchical) run.

    ``budgets`` maps class -> SLO budget in slices (the
    :class:`~repro.fleet.hierarchy.CellRouter` budgets); a class without
    one is judged against the fleet-wide ``res.slo_ns``. Miss accounting
    matches :func:`summarize`: rejected + unfinished requests count as
    misses of their class."""
    res = getattr(res, "result", res)
    budgets = budgets or {}
    default = budgets.get("default")
    out: Dict[str, Dict] = {}
    groups: Dict[str, Dict[str, list]] = {}
    for r in res.completed:
        groups.setdefault(r.slo_class, {"lat": [], "rej": 0, "unf": 0})[
            "lat"].append(r.latency_ns)
    for r in res.rejected:
        groups.setdefault(r.slo_class, {"lat": [], "rej": 0, "unf": 0})[
            "rej"] += 1
    for r in res.unfinished:
        groups.setdefault(r.slo_class, {"lat": [], "rej": 0, "unf": 0})[
            "unf"] += 1
    for cls, g in sorted(groups.items()):
        budget = budgets.get(cls, default)
        slo_ns = (budget * res.t_slice_ns if budget is not None
                  else res.slo_ns)
        lat = g["lat"]
        n = len(lat) + g["rej"] + g["unf"]
        misses = sum(t > slo_ns for t in lat) + g["rej"] + g["unf"]
        out[cls] = {
            "n_submitted": n,
            "n_completed": len(lat),
            "n_rejected": g["rej"],
            "n_unfinished": g["unf"],
            "slo_ms": slo_ns / 1e6,
            "deadline_miss_rate": misses / n if n else 0.0,
            "p99_ms": (percentile([t / 1e6 for t in lat], 99)
                       if lat else 0.0),
        }
    return out


def summarize(res: FleetResult) -> FleetSummary:
    # a HierarchyResult wraps its FleetResult; accept both
    res = getattr(res, "result", res)
    lat_ms = [r.latency_ns / 1e6 for r in res.completed]
    slo_ms = res.slo_ns / 1e6
    n_sub = (len(res.completed) + len(res.rejected)
             + len(res.unfinished))
    # rejected and never-finished requests both count against the SLO
    misses = (sum(t > slo_ms for t in lat_ms) + len(res.rejected)
              + len(res.unfinished))
    all_reports = [r for reps in res.reports.values() for r in reps]
    energy_pj = sum(r.energy_pj for r in all_reports)
    tokens = sum(r.tokens for r in res.completed)
    backlogs = [r.n_tasks for r in all_reports]
    if not lat_ms:
        # degenerate trace (zero completions): report zeros explicitly
        # instead of percentile([]) = NaN / 0-token division
        return FleetSummary(
            trace=res.trace, n_slices=res.n_slices,
            n_engines=len(res.reports), n_submitted=n_sub,
            n_completed=0, n_rejected=len(res.rejected),
            n_unfinished=len(res.unfinished),
            p50_ms=0.0, p95_ms=0.0, p99_ms=0.0, mean_ms=0.0,
            slo_ms=slo_ms,
            deadline_miss_rate=misses / n_sub if n_sub else 0.0,
            energy_uj=energy_pj * 1e-6, energy_per_token_uj=0.0,
            tokens=0,
            migrations=sum(r.moved_weights > 0 for r in all_reports),
            weights_moved=sum(r.moved_weights for r in all_reports),
            mean_backlog=float(np.mean(backlogs)) if backlogs else 0.0,
            peak_backlog=max(backlogs) if backlogs else 0,
            degenerate=True)
    return FleetSummary(
        trace=res.trace,
        n_slices=res.n_slices,
        n_engines=len(res.reports),
        n_submitted=n_sub,
        n_completed=len(res.completed),
        n_rejected=len(res.rejected),
        n_unfinished=len(res.unfinished),
        p50_ms=percentile(lat_ms, 50),
        p95_ms=percentile(lat_ms, 95),
        p99_ms=percentile(lat_ms, 99),
        mean_ms=float(np.mean(lat_ms)) if lat_ms else float("nan"),
        slo_ms=slo_ms,
        deadline_miss_rate=misses / n_sub if n_sub else 0.0,
        energy_uj=energy_pj * 1e-6,
        energy_per_token_uj=(energy_pj * 1e-6 / tokens) if tokens else 0.0,
        tokens=tokens,
        migrations=sum(r.moved_weights > 0 for r in all_reports),
        weights_moved=sum(r.moved_weights for r in all_reports),
        mean_backlog=float(np.mean(backlogs)) if backlogs else 0.0,
        peak_backlog=max(backlogs) if backlogs else 0,
    )
