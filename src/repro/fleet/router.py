"""SLO-aware request routing across a pool of serve engines.

One :class:`EngineWorker` = one HH-PIM serve engine: a
``TimeSliceScheduler`` re-solving weight placement every slice (the paper's
per-device loop), plus a per-engine :class:`~repro.fleet.forecast.Forecaster`
feeding the scheduler's ``lookup_tasks`` hook so migrations happen
*proactively*, and optionally a real ``HeteroServeEngine`` so placement
changes are functionally exercised (weights re-tiered, tokens decoded).

The fleet runs the paper's buffering discipline at pool scale: requests
arriving during slice ``s`` are dispatched to a worker's backlog and become
executable in slice ``s+1``; each slice a worker drains as much backlog as
fits its current placement's capacity (``cap_to_capacity``), carrying the
rest. A request's latency is measured from the start of its arrival slice
to its completion instant inside its execution slice, so the paper's <= 2T
operational-latency bound is exactly the default SLO (``slo_slices=2``).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence

from repro import obs
from repro.core.scheduler import SliceReport, TimeSliceScheduler
from repro.fleet.forecast import Forecaster, NoForecast
from repro.fleet.traces import Trace

POLICIES = ("round_robin", "least_loaded", "slo")

#: admission outcomes recorded per request (reason codes: DESIGN.md SS.8)
ADMIT_ACCEPT = "accept"           # routed to the preferred worker
ADMIT_DEFER = "defer"             # preferred queue full; fell back
ADMIT_REJECT = "reject"           # every queue at the admission limit


@dataclasses.dataclass
class FleetRequest:
    rid: int
    arrival_slice: int
    tokens: int = 8               # decoded tokens = one scheduler task
    worker: Optional[int] = None
    finish_slice: Optional[int] = None
    latency_ns: Optional[float] = None
    rejected: bool = False
    slo_class: str = "default"    # per-class SLO/queue-wait attribution
    tenant: str = "-"             # owning tenant ("-" = untenanted)
    admission: Optional[str] = None   # ADMIT_* outcome stamped by the router
    # hierarchical routing (repro.fleet.hierarchy): cell id + the wait the
    # global tier predicted at admission (feeds the cell's bias EWMA)
    cell: Optional[int] = None
    wait_est: Optional[float] = None


class EngineWorker:
    """One engine of the fleet: scheduler + forecaster + backlog queue."""

    def __init__(self, wid: int, sched: TimeSliceScheduler,
                 forecaster: Optional[Forecaster] = None, *,
                 hetero=None, substrate=None, forecast_margin: float = 1.0):
        self.wid = wid
        self.sched = sched
        self.forecaster = forecaster or NoForecast()
        self.hetero = hetero              # optional HeteroServeEngine
        # optional Substrate: placement application is routed through its
        # apply_placement (functional re-tiering where the platform has one)
        self.substrate = substrate
        self.forecast_margin = forecast_margin
        self.backlog: List[FleetRequest] = []
        self.reports: List[SliceReport] = []
        self.tokens_decoded = 0
        self._arrived_this_slice = 0

    # -- routing signals ---------------------------------------------------
    @property
    def t_slice_ns(self) -> float:
        return self.sched.t_slice_ns

    def t_task_est_ns(self) -> float:
        """Per-task time under the worker's CURRENT placement (what a newly
        routed request would experience before any re-placement)."""
        return self.sched.em.task_cost(self.sched.placement).t_task_ns

    def expected_wait_slices(self, extra: int = 0) -> float:
        """Backlog drain time, in slices, if `extra` more tasks were added."""
        t = self.t_task_est_ns()
        if t <= 0:
            return 0.0
        return (len(self.backlog) + extra) * t / self.t_slice_ns

    # -- per-slice protocol ------------------------------------------------
    def enqueue(self, req: FleetRequest) -> None:
        req.worker = self.wid
        self.backlog.append(req)
        self._arrived_this_slice += 1

    def end_of_slice(self) -> None:
        """Feed this slice's arrival count to the forecaster."""
        self.forecaster.observe(self._arrived_this_slice)
        self._arrived_this_slice = 0

    def step(self, slice_idx: int) -> List[FleetRequest]:
        """Execute one slice against the buffered backlog; returns the
        requests completed this slice (latency stamped)."""
        _obs = obs.enabled()
        _t0 = obs.now_ns() if _obs else 0
        n_backlog = len(self.backlog)
        pred = int(math.ceil(self.forecaster.predict()
                             * self.forecast_margin))
        lookup = max(n_backlog, pred)
        rep = self.sched.step(n_backlog, lookup_tasks=lookup,
                              cap_to_capacity=True)
        self.reports.append(rep)
        n_done = rep.n_done
        done, self.backlog = self.backlog[:n_done], self.backlog[n_done:]
        T = self.t_slice_ns
        t_task = rep.t_task_ns
        for i, req in enumerate(done):
            req.finish_slice = slice_idx
            req.latency_ns = ((slice_idx - req.arrival_slice) * T
                              + rep.t_move_ns + (i + 1) * t_task)
            self.tokens_decoded += req.tokens
            if _obs:
                # queue wait in slices, attributed per SLO class
                obs.observe("fleet.queue_wait_slices",
                            slice_idx - req.arrival_slice,
                            buckets=obs.WAIT_SLICE_BUCKETS,
                            cls=req.slo_class, tenant=req.tenant)
        if self.substrate is not None:
            self.substrate.apply_placement(rep.placement, sink=self.hetero)
        elif self.hetero is not None:
            self.hetero.apply_placement(rep.placement)
        if self.hetero is not None and n_done:
            self.hetero.decode(n_done)
        if _obs:
            obs.complete("worker.step", _t0, cat="fleet", tid=self.wid,
                         args={"wid": self.wid, "backlog": n_backlog,
                               "forecast": pred, "n_done": n_done,
                               "carried": len(self.backlog),
                               "moved_weights": rep.moved_weights})
        return done


class FleetRouter:
    """Dispatches arrivals to workers; optionally rejects (admission
    control) when every queue is past ``admission_limit`` tasks."""

    def __init__(self, workers: Sequence[EngineWorker],
                 policy: str = "slo",
                 admission_limit: Optional[int] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.workers = list(workers)
        self.policy = policy
        self.admission_limit = admission_limit
        self._rr = 0

    def _score(self, w: EngineWorker) -> float:
        if self.policy == "least_loaded":
            return len(w.backlog)
        # "slo": expected completion time of the new request, in slices,
        # normalizing out heterogeneous engine speeds
        return w.expected_wait_slices(1)

    def _admits(self, i: int) -> bool:
        return (self.admission_limit is None
                or len(self.workers[i].backlog) < self.admission_limit)

    def route(self, req: FleetRequest) -> bool:
        """Assign ``req`` to a worker; False => rejected by admission (only
        when EVERY queue is at the limit - a full preferred worker falls
        back to the best still-admitting one). Backlogs update as each
        request is enqueued, so scores stay fresh within a slice.

        The admission outcome (accept / defer / reject + reason code) is
        stamped on the request and counted in the metrics registry."""
        n = len(self.workers)
        if self.policy == "round_robin":
            order = [(self._rr + k) % n for k in range(n)]
            self._rr = (self._rr + 1) % n
        else:
            order = sorted(range(len(self.workers)),
                           key=lambda j: (self._score(self.workers[j]), j))
        i = next((j for j in order if self._admits(j)), None)
        if i is None:
            req.rejected = True
            req.admission = ADMIT_REJECT
            if obs.enabled():
                obs.counter("fleet.admission", decision=ADMIT_REJECT,
                            reason="all_queues_full", cls=req.slo_class,
                            tenant=req.tenant)
                obs.instant("fleet.reject", cat="fleet",
                            args={"rid": req.rid,
                                  "reason": "all_queues_full",
                                  "limit": self.admission_limit})
            return False
        req.admission = ADMIT_ACCEPT if i == order[0] else ADMIT_DEFER
        if obs.enabled():
            if req.admission == ADMIT_DEFER:
                obs.counter("fleet.admission", decision=ADMIT_DEFER,
                            reason="preferred_full", cls=req.slo_class,
                            tenant=req.tenant)
            else:
                obs.counter("fleet.admission", decision=ADMIT_ACCEPT,
                            reason="ok", cls=req.slo_class,
                            tenant=req.tenant)
        self.workers[i].enqueue(req)
        return True


@dataclasses.dataclass
class FleetResult:
    trace: str
    completed: List[FleetRequest]
    rejected: List[FleetRequest]
    # still queued when the drain cutoff fired (overload); counted as SLO
    # misses by metrics.summarize so saturation cannot deflate miss rates
    unfinished: List[FleetRequest]
    reports: Dict[int, List[SliceReport]]   # worker id -> per-slice reports
    t_slice_ns: float
    slo_ns: float
    n_slices: int


def _nearest_rank(xs: List[float], q: float) -> Optional[float]:
    """Nearest-rank percentile without numpy (flight-recorder trigger
    signal; the reporting-grade percentiles stay in fleet.metrics)."""
    if not xs:
        return None
    ordered = sorted(xs)
    k = max(math.ceil(q / 100.0 * len(ordered)) - 1, 0)
    return ordered[min(k, len(ordered) - 1)]


class Fleet:
    """Trace-driven multi-engine serving loop."""

    def __init__(self, workers: Sequence[EngineWorker], *,
                 policy: str = "slo",
                 admission_limit: Optional[int] = None,
                 slo_slices: float = 2.0,
                 tokens_per_request: int = 8):
        if not workers:
            raise ValueError("fleet needs at least one worker")
        self.workers = list(workers)
        self.router = FleetRouter(self.workers, policy=policy,
                                  admission_limit=admission_limit)
        self.slo_slices = slo_slices
        self.tokens_per_request = tokens_per_request
        self._rid = itertools.count()

    def _record_frame(self, recorder, s: int, n_arr: int,
                      done_now: List[FleetRequest], rejected_now: int,
                      trace: Trace, lat_ms: List[float], n_miss: int,
                      slo_ms: float) -> None:
        """One flight-recorder frame: per-engine state + the slice's
        admission outcomes + fleet-wide LUT-cache counters (schema:
        DESIGN.md SS.8), then the SLO trigger check on the running
        miss rate / p99."""
        reg = obs.metrics()
        engines = []
        for w in self.workers:
            rep = w.reports[-1] if w.reports else None
            engines.append({
                "wid": w.wid,
                "queue_depth": len(w.backlog),
                "n_done": rep.n_done if rep else 0,
                "placement": dict(rep.placement) if rep else {},
                "moved_weights": rep.moved_weights if rep else 0,
                "deadline_met": rep.deadline_met if rep else True,
                "forecast": round(w.forecaster.predict(), 3),
            })
        admitted = n_arr - rejected_now
        # running denominator = requests with a known outcome so far:
        # completed (lat_ms) + rejected (n_miss minus late completions)
        denom = len(lat_ms) + (n_miss - sum(x > slo_ms for x in lat_ms))
        miss_rate = (n_miss / denom) if denom else 0.0
        recorder.record(s, {
            "arrivals": n_arr,
            "admitted": admitted,
            "rejected": rejected_now,
            "completed": len(done_now),
            "engines": engines,
            "lut_cache": {"builds": reg.value("compiler.lut.build"),
                          "hits": reg.value("compiler.lut.hit"),
                          "sched_hits": reg.value("sched.lut.hit"),
                          "sched_misses": reg.value("sched.lut.miss")},
            "running": {"deadline_miss_rate": round(miss_rate, 4),
                        "p99_ms": _nearest_rank(lat_ms, 99)},
        })
        recorder.check(deadline_miss_rate=miss_rate,
                       p99_ms=_nearest_rank(lat_ms, 99),
                       context={"trace": trace.name, "slice": s,
                                "slo_ms": slo_ms})

    def run(self, trace: Trace, *, max_drain_slices: int = 200,
            verbose_cb=None) -> FleetResult:
        completed: List[FleetRequest] = []
        rejected: List[FleetRequest] = []
        s = 0
        n_slices = len(trace.arrivals)
        recorder = obs.flight_recorder()
        if obs.enabled():
            for w in self.workers:
                obs.tracer().name_track(w.wid, f"engine-{w.wid}")
            obs.instant("fleet.run", cat="fleet",
                        args={"trace": trace.name, "engines":
                              len(self.workers),
                              "policy": self.router.policy})
        # running SLO signals for the flight recorder: latency of every
        # completed request so far (ms) + misses incl. rejections
        slo_ms = self.slo_slices * self.workers[0].t_slice_ns / 1e6
        lat_ms: List[float] = []
        n_miss = 0
        while True:
            draining = s >= n_slices
            if draining and (all(not w.backlog for w in self.workers)
                             or s >= n_slices + max_drain_slices):
                break
            _obs = obs.enabled()
            _t0 = obs.now_ns() if _obs else 0
            # 1) execute the backlog buffered from earlier slices
            done_now: List[FleetRequest] = []
            for w in self.workers:
                done_now.extend(w.step(s))
            completed.extend(done_now)
            # 2) dispatch this slice's arrivals (executable next slice)
            n_arr = trace.arrivals[s] if not draining else 0
            rejected_now = 0
            for _ in range(n_arr):
                req = FleetRequest(rid=next(self._rid), arrival_slice=s,
                                   tokens=self.tokens_per_request)
                if not self.router.route(req):
                    rejected.append(req)
                    rejected_now += 1
            for w in self.workers:
                w.end_of_slice()
            if _obs:
                obs.complete("fleet.slice", _t0, cat="fleet",
                             args={"slice": s, "arrivals": n_arr,
                                   "done": len(done_now),
                                   "rejected": rejected_now,
                                   "backlog": sum(len(w.backlog)
                                                  for w in self.workers)})
            if recorder is not None:
                n_miss += rejected_now
                for r in done_now:
                    lat_ms.append(r.latency_ns / 1e6)
                    n_miss += r.latency_ns / 1e6 > slo_ms
                self._record_frame(recorder, s, n_arr, done_now,
                                   rejected_now, trace, lat_ms, n_miss,
                                   slo_ms)
            if verbose_cb is not None:
                verbose_cb(s, n_arr, done_now, self.workers)
            s += 1
        T = self.workers[0].t_slice_ns
        unfinished = [r for w in self.workers for r in w.backlog]
        if recorder is not None:
            # the drain cutoff strands backlog: that is an SLO event too
            n_miss += len(unfinished)
            n_sub = len(completed) + len(rejected) + len(unfinished)
            recorder.check(
                deadline_miss_rate=(n_miss / n_sub) if n_sub else 0.0,
                p99_ms=_nearest_rank(lat_ms, 99),
                context={"trace": trace.name, "phase": "end_of_run",
                         "slo_ms": slo_ms, "n_slices": s})
        return FleetResult(
            trace=trace.name, completed=completed, rejected=rejected,
            unfinished=unfinished,
            reports={w.wid: w.reports for w in self.workers},
            t_slice_ns=T, slo_ns=self.slo_slices * T, n_slices=s)
