"""SLO-aware request routing across a pool of serve engines.

One :class:`EngineWorker` = one HH-PIM serve engine: a
``TimeSliceScheduler`` re-solving weight placement every slice (the paper's
per-device loop), plus a per-engine :class:`~repro.fleet.forecast.Forecaster`
feeding the scheduler's ``lookup_tasks`` hook so migrations happen
*proactively*, and optionally a real ``HeteroServeEngine`` so placement
changes are functionally exercised (weights re-tiered, tokens decoded).

The fleet runs the paper's buffering discipline at pool scale: requests
arriving during slice ``s`` are dispatched to a worker's backlog and become
executable in slice ``s+1``; each slice a worker drains as much backlog as
fits its current placement's capacity (``cap_to_capacity``), carrying the
rest. A request's latency is measured from the start of its arrival slice
to its completion instant inside its execution slice, so the paper's <= 2T
operational-latency bound is exactly the default SLO (``slo_slices=2``).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler import SliceReport, TimeSliceScheduler
from repro.fleet.forecast import Forecaster, NoForecast
from repro.fleet.traces import Trace

POLICIES = ("round_robin", "least_loaded", "slo")


@dataclasses.dataclass
class FleetRequest:
    rid: int
    arrival_slice: int
    tokens: int = 8               # decoded tokens = one scheduler task
    worker: Optional[int] = None
    finish_slice: Optional[int] = None
    latency_ns: Optional[float] = None
    rejected: bool = False


class EngineWorker:
    """One engine of the fleet: scheduler + forecaster + backlog queue."""

    def __init__(self, wid: int, sched: TimeSliceScheduler,
                 forecaster: Optional[Forecaster] = None, *,
                 hetero=None, substrate=None, forecast_margin: float = 1.0):
        self.wid = wid
        self.sched = sched
        self.forecaster = forecaster or NoForecast()
        self.hetero = hetero              # optional HeteroServeEngine
        # optional Substrate: placement application is routed through its
        # apply_placement (functional re-tiering where the platform has one)
        self.substrate = substrate
        self.forecast_margin = forecast_margin
        self.backlog: List[FleetRequest] = []
        self.reports: List[SliceReport] = []
        self.tokens_decoded = 0
        self._arrived_this_slice = 0

    # -- routing signals ---------------------------------------------------
    @property
    def t_slice_ns(self) -> float:
        return self.sched.t_slice_ns

    def t_task_est_ns(self) -> float:
        """Per-task time under the worker's CURRENT placement (what a newly
        routed request would experience before any re-placement)."""
        return self.sched.em.task_cost(self.sched.placement).t_task_ns

    def expected_wait_slices(self, extra: int = 0) -> float:
        """Backlog drain time, in slices, if `extra` more tasks were added."""
        t = self.t_task_est_ns()
        if t <= 0:
            return 0.0
        return (len(self.backlog) + extra) * t / self.t_slice_ns

    # -- per-slice protocol ------------------------------------------------
    def enqueue(self, req: FleetRequest) -> None:
        req.worker = self.wid
        self.backlog.append(req)
        self._arrived_this_slice += 1

    def end_of_slice(self) -> None:
        """Feed this slice's arrival count to the forecaster."""
        self.forecaster.observe(self._arrived_this_slice)
        self._arrived_this_slice = 0

    def step(self, slice_idx: int) -> List[FleetRequest]:
        """Execute one slice against the buffered backlog; returns the
        requests completed this slice (latency stamped)."""
        n_backlog = len(self.backlog)
        pred = int(math.ceil(self.forecaster.predict()
                             * self.forecast_margin))
        lookup = max(n_backlog, pred)
        rep = self.sched.step(n_backlog, lookup_tasks=lookup,
                              cap_to_capacity=True)
        self.reports.append(rep)
        n_done = rep.n_done
        done, self.backlog = self.backlog[:n_done], self.backlog[n_done:]
        T = self.t_slice_ns
        t_task = rep.t_task_ns
        for i, req in enumerate(done):
            req.finish_slice = slice_idx
            req.latency_ns = ((slice_idx - req.arrival_slice) * T
                              + rep.t_move_ns + (i + 1) * t_task)
            self.tokens_decoded += req.tokens
        if self.substrate is not None:
            self.substrate.apply_placement(rep.placement, sink=self.hetero)
        elif self.hetero is not None:
            self.hetero.apply_placement(rep.placement)
        if self.hetero is not None and n_done:
            self.hetero.decode(n_done)
        return done


class FleetRouter:
    """Dispatches arrivals to workers; optionally rejects (admission
    control) when every queue is past ``admission_limit`` tasks."""

    def __init__(self, workers: Sequence[EngineWorker],
                 policy: str = "slo",
                 admission_limit: Optional[int] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.workers = list(workers)
        self.policy = policy
        self.admission_limit = admission_limit
        self._rr = 0

    def _score(self, w: EngineWorker) -> float:
        if self.policy == "least_loaded":
            return len(w.backlog)
        # "slo": expected completion time of the new request, in slices,
        # normalizing out heterogeneous engine speeds
        return w.expected_wait_slices(1)

    def _admits(self, i: int) -> bool:
        return (self.admission_limit is None
                or len(self.workers[i].backlog) < self.admission_limit)

    def route(self, req: FleetRequest) -> bool:
        """Assign ``req`` to a worker; False => rejected by admission (only
        when EVERY queue is at the limit - a full preferred worker falls
        back to the best still-admitting one). Backlogs update as each
        request is enqueued, so scores stay fresh within a slice."""
        n = len(self.workers)
        if self.policy == "round_robin":
            order = [(self._rr + k) % n for k in range(n)]
            self._rr = (self._rr + 1) % n
        else:
            order = sorted(range(len(self.workers)),
                           key=lambda j: (self._score(self.workers[j]), j))
        i = next((j for j in order if self._admits(j)), None)
        if i is None:
            req.rejected = True
            return False
        self.workers[i].enqueue(req)
        return True


@dataclasses.dataclass
class FleetResult:
    trace: str
    completed: List[FleetRequest]
    rejected: List[FleetRequest]
    # still queued when the drain cutoff fired (overload); counted as SLO
    # misses by metrics.summarize so saturation cannot deflate miss rates
    unfinished: List[FleetRequest]
    reports: Dict[int, List[SliceReport]]   # worker id -> per-slice reports
    t_slice_ns: float
    slo_ns: float
    n_slices: int


class Fleet:
    """Trace-driven multi-engine serving loop."""

    def __init__(self, workers: Sequence[EngineWorker], *,
                 policy: str = "slo",
                 admission_limit: Optional[int] = None,
                 slo_slices: float = 2.0,
                 tokens_per_request: int = 8):
        if not workers:
            raise ValueError("fleet needs at least one worker")
        self.workers = list(workers)
        self.router = FleetRouter(self.workers, policy=policy,
                                  admission_limit=admission_limit)
        self.slo_slices = slo_slices
        self.tokens_per_request = tokens_per_request
        self._rid = itertools.count()

    def run(self, trace: Trace, *, max_drain_slices: int = 200,
            verbose_cb=None) -> FleetResult:
        completed: List[FleetRequest] = []
        rejected: List[FleetRequest] = []
        s = 0
        n_slices = len(trace.arrivals)
        while True:
            draining = s >= n_slices
            if draining and (all(not w.backlog for w in self.workers)
                             or s >= n_slices + max_drain_slices):
                break
            # 1) execute the backlog buffered from earlier slices
            done_now: List[FleetRequest] = []
            for w in self.workers:
                done_now.extend(w.step(s))
            completed.extend(done_now)
            # 2) dispatch this slice's arrivals (executable next slice)
            n_arr = trace.arrivals[s] if not draining else 0
            for _ in range(n_arr):
                req = FleetRequest(rid=next(self._rid), arrival_slice=s,
                                   tokens=self.tokens_per_request)
                if not self.router.route(req):
                    rejected.append(req)
            for w in self.workers:
                w.end_of_slice()
            if verbose_cb is not None:
                verbose_cb(s, n_arr, done_now, self.workers)
            s += 1
        T = self.workers[0].t_slice_ns
        unfinished = [r for w in self.workers for r in w.backlog]
        return FleetResult(
            trace=trace.name, completed=completed, rejected=rejected,
            unfinished=unfinished,
            reports={w.wid: w.reports for w in self.workers},
            t_slice_ns=T, slo_ns=self.slo_slices * T, n_slices=s)
