"""Request-arrival traces for the serving fleet.

Generalizes the paper's six fixed 50-slice workload cases (Fig. 4,
``repro.core.workloads``) into parameterized stochastic traffic models plus
deterministic replay:

  * ``poisson``      - iid Poisson arrivals (open-loop steady traffic),
  * ``mmpp``         - 2-state Markov-modulated Poisson process (bursty
                       traffic with sojourns in a low- and a high-rate
                       state; the classic serving-burst model),
  * ``diurnal``      - sinusoidal day/night rate with Poisson noise,
  * ``flash_crowd``  - quiet baseline, then a sudden spike decaying
                       geometrically (thundering-herd / retweet storm),
  * ``ramp``         - linear rate ramp from low to high (load test),
  * ``replay``       - verbatim replay of a recorded per-slice count list,
  * the six paper cases, re-exported under their original names.

Every generator is seeded and returns a :class:`Trace`; equal seeds give
equal traces, so fleet experiments are reproducible end to end.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

import numpy as np

from repro.core import workloads

DEFAULT_SLICES = 50


@dataclasses.dataclass(frozen=True)
class Trace:
    name: str
    arrivals: List[int]           # requests arriving per time slice

    def __len__(self) -> int:
        return len(self.arrivals)

    @property
    def total(self) -> int:
        return int(sum(self.arrivals))

    @property
    def peak(self) -> int:
        return max(self.arrivals) if self.arrivals else 0

    def truncated(self, max_requests: int) -> "Trace":
        """Clip the trace once ``max_requests`` total arrivals are reached
        (CLI ``--requests`` budget)."""
        out: List[int] = []
        left = max_requests
        for a in self.arrivals:
            take = min(a, left)
            out.append(take)
            left -= take
            if left <= 0:
                break
        return Trace(self.name, out)


def _clip(xs: np.ndarray) -> List[int]:
    return [int(max(x, 0)) for x in xs]


def poisson_trace(n_slices: int = DEFAULT_SLICES, *, rate: float = 4.0,
                  seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    return Trace(f"poisson(rate={rate})",
                 _clip(rng.poisson(rate, size=n_slices)))


def mmpp_trace(n_slices: int = DEFAULT_SLICES, *, rate_low: float = 2.0,
               rate_high: float = 12.0, p_up: float = 0.15,
               p_down: float = 0.3, seed: int = 0) -> Trace:
    """2-state MMPP: in the low state switch up w.p. ``p_up`` per slice, in
    the high state switch down w.p. ``p_down``; arrivals are Poisson at the
    current state's rate. Mean high-state sojourn = 1/p_down slices, so
    bursts persist across slices - the regime where forecasting pays."""
    rng = np.random.default_rng(seed)
    arrivals = []
    high = False
    for _ in range(n_slices):
        if high:
            high = rng.random() >= p_down
        else:
            high = rng.random() < p_up
        arrivals.append(rng.poisson(rate_high if high else rate_low))
    return Trace(f"mmpp({rate_low}/{rate_high})", _clip(np.array(arrivals)))


def diurnal_trace(n_slices: int = DEFAULT_SLICES, *, base: float = 2.0,
                  peak: float = 10.0, period: int = 24,
                  seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    t = np.arange(n_slices)
    rate = base + (peak - base) * 0.5 * (1 - np.cos(2 * np.pi * t / period))
    return Trace(f"diurnal(period={period})", _clip(rng.poisson(rate)))


def flash_crowd_trace(n_slices: int = DEFAULT_SLICES, *, base: float = 2.0,
                      spike_slice: int = None, spike: float = 18.0,
                      decay: float = 0.6, seed: int = 0) -> Trace:
    """Quiet Poisson baseline; at ``spike_slice`` the rate jumps to
    ``spike`` and decays geometrically back to base."""
    rng = np.random.default_rng(seed)
    if spike_slice is None:
        spike_slice = n_slices // 3
    rate = np.full(n_slices, float(base))
    for i in range(spike_slice, n_slices):
        extra = (spike - base) * decay ** (i - spike_slice)
        if extra < 0.25:
            break
        rate[i] += extra
    return Trace(f"flash(spike={spike})", _clip(rng.poisson(rate)))


def ramp_trace(n_slices: int = DEFAULT_SLICES, *, start: float = 1.0,
               end: float = 12.0, seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    rate = np.linspace(start, end, n_slices)
    return Trace(f"ramp({start}->{end})", _clip(rng.poisson(rate)))


def replay_trace(arrivals: Sequence[int], name: str = "replay") -> Trace:
    return Trace(name, [int(a) for a in arrivals])


def workload_trace(case: str) -> Trace:
    """Adapter: one of the paper's six fixed cases as a Trace."""
    return Trace(case, list(workloads.SCENARIOS[case]))


TRACES: Dict[str, Callable[..., Trace]] = {
    "poisson": poisson_trace,
    "mmpp": mmpp_trace,
    "diurnal": diurnal_trace,
    "flash": flash_crowd_trace,
    "ramp": ramp_trace,
}
# traffic classes where load changes faster than a reactive scheduler can
# migrate - the benchmark's forecasting-vs-reactive comparison set
BURSTY = ("mmpp", "flash", "ramp")


def make_trace(name: str, n_slices: int = DEFAULT_SLICES, seed: int = 0,
               **kw) -> Trace:
    """Trace factory: stochastic generators by short name, or any of the
    paper's ``case*`` scenario names (deterministic, fixed length)."""
    if name in TRACES:
        return TRACES[name](n_slices, seed=seed, **kw)
    if name in workloads.SCENARIOS:
        return workload_trace(name)
    raise ValueError(
        f"unknown trace {name!r}; choose from {sorted(TRACES)} or "
        f"{sorted(workloads.SCENARIOS)}")
