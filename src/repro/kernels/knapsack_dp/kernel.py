"""Pallas TPU kernel for the knapsack DP inner loop (Algorithm 1).

The LUT build is on the serving runtime's critical path at every mesh
reconfiguration (and the paper bounds it to <=1 % of a time slice), so the
O(T*K) table build is worth a kernel. The t-loop is inherently sequential;
the K axis vectorizes on the VPU (8x128 lanes).

Tiling: the table is tiled over K into (T+1, bk) column panels that live in
VMEM; the in-kernel shift across the k-1 boundary needs the last column of
the previous panel, which is passed via a (T+1, 1) carry column. Grid is
(K/bk,) - panels are independent given the carry, and the t-recurrence runs
inside as a fori_loop over rows.

The per-item costs (t_i, e_i) enter as SMEM scalar operands, not as
static jit arguments: the LUT builder folds every storage space (and, on
straggler rebuilds, every slowdown signature) with different costs, so
baking them into the compile key would recompile the kernel per cost
value - one compile per table shape instead.

VMEM: (T+1)*(bk+2)*4 B; defaults (T=2048, bk=512) use ~4.2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INF = jnp.float32(jnp.inf)


def _dp_kernel(t_ref, e_ref, dp_ref, carry_ref, o_ref, *, T1: int):
    """One K-panel: run the t-recurrence, consuming the k=-1 carry column.

    ``t_ref``/``e_ref`` are (1, 1) SMEM scalars holding the item's tick
    cost and energy."""
    t_i = t_ref[0, 0]
    e_i = e_ref[0, 0]

    def body(t, _):
        row = dp_ref[t, :]
        prev_t = jnp.maximum(t - t_i, 0)
        # dp_new[t, k] uses dp_new[t - t_i, k - 1]: read the already-updated
        # rows of the output panel, shifted by one k (carry provides k=-1;
        # carry holds the *updated* last column of the previous panel).
        shifted = jnp.concatenate([carry_ref[prev_t, :], o_ref[prev_t, :-1]])
        take = jnp.where(t >= t_i, shifted + e_i, float("inf"))
        o_ref[t, :] = jnp.minimum(row, take)
        return 0

    jax.lax.fori_loop(0, T1, body, 0, unroll=False)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def dp_space_update_pallas(dp_prev: jnp.ndarray, *, t_i, e_i,
                           bk: int = 512, interpret: bool = False
                           ) -> jnp.ndarray:
    """Fold one storage space into the (T+1, K+1) DP table.

    K-panels have a sequential dependency through the carry column, so the
    wrapper loops panels in python (K/bk steps, each a pallas_call); within
    a panel the VPU processes bk lanes per row step. ``t_i``/``e_i`` may
    be python numbers or traced scalars - they are shipped to the kernel
    as SMEM operands, so the compile cache is keyed on the table shape
    and ``bk`` only.
    """
    T1, K1 = dp_prev.shape
    pad_k = (-K1) % bk
    dp = jnp.pad(dp_prev, ((0, 0), (0, pad_k)), constant_values=jnp.inf)
    Kp = dp.shape[1]

    t_arr = jnp.asarray(t_i, jnp.int32).reshape(1, 1)
    e_arr = jnp.asarray(e_i, jnp.float32).reshape(1, 1)
    kernel = functools.partial(_dp_kernel, T1=T1)
    carry = jnp.full((T1, 1), INF, dtype=dp.dtype)   # k=-1 column
    panels = []
    for p in range(Kp // bk):
        panel = jax.lax.slice_in_dim(dp, p * bk, (p + 1) * bk, axis=1)
        panel_out = pl.pallas_call(
            kernel,
            grid=(1,),
            in_specs=[
                pl.BlockSpec((1, 1), lambda i: (0, 0),
                             memory_space=pltpu.SMEM),
                pl.BlockSpec((1, 1), lambda i: (0, 0),
                             memory_space=pltpu.SMEM),
                pl.BlockSpec((T1, bk), lambda i: (0, 0)),
                pl.BlockSpec((T1, 1), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((T1, bk), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((T1, bk), dp.dtype),
            interpret=interpret,
        )(t_arr, e_arr, panel, carry)
        carry = panel_out[:, -1:]
        panels.append(panel_out)
    result = jnp.concatenate(panels, axis=1)[:, :K1]
    return result
