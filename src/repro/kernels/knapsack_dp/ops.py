"""Public op: per-cluster Algorithm-1 DP table, kernel- or ref-backed.

This is the production table builder behind ``build_lut(method="dp")``
(repro.core.placement): the per-space fold runs on one of

  * ``pallas``           - the TPU kernel (kernel.py),
  * ``pallas_interpret`` - the same kernel under the Pallas interpreter,
    so the kernel *code path* is exercised on CPU runners (CI),
  * ``ref``              - the jitted pure-jnp oracle (ref.py), the CPU
    production backend.

``backend="auto"`` resolves to ``pallas`` on TPU and ``ref`` elsewhere;
the ``REPRO_KNAPSACK_BACKEND`` environment variable overrides the auto
choice (CI sets it to ``pallas_interpret`` to test the kernel path on
CPU runners, where auto would otherwise never select it).

``return_stages=True`` returns the stacked per-space tables
``(n+1, T+1, K+1)`` (stage 0 is the k=0 base table) that
``repro.core.placement.backtrace_tables`` walks to recover placements.
"""
from __future__ import annotations

import os
from typing import Sequence

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.knapsack_dp.kernel import dp_space_update_pallas
from repro.kernels.knapsack_dp.ref import dp_space_update_ref

BACKEND_ENV = "REPRO_KNAPSACK_BACKEND"

# t_i / e_i passed as traced scalars => one compile per table shape, not
# one per (t_i, e_i) value (the LUT builder folds 2 spaces per cluster
# with different costs).
_ref_fold = jax.jit(dp_space_update_ref)


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


BACKENDS = ("ref", "pallas", "pallas_interpret")


def resolve_backend(backend: str = "auto") -> str:
    """Resolve ``auto`` to a concrete backend (env override wins) and
    validate the result, so a typo'd env value fails with the valid
    names instead of an opaque lowering error."""
    if backend == "auto":
        backend = (os.environ.get(BACKEND_ENV)
                   or ("pallas" if _on_tpu() else "ref"))
    if backend not in BACKENDS:
        raise ValueError(f"unknown knapsack_dp backend {backend!r}; "
                         f"one of {BACKENDS} (or 'auto', env var "
                         f"{BACKEND_ENV})")
    return backend


def knapsack_dp(t_items: Sequence[int], e_items: Sequence[float],
                T: int, K: int, *, backend: str = "auto",
                bk: int = 512, return_stages: bool = False) -> jnp.ndarray:
    """Build the (T+1, K+1) min-energy table for one cluster's spaces.

    backend: "auto" | "pallas" | "pallas_interpret" | "ref".
    return_stages: also return every intermediate per-space table,
      stacked to (n+1, T+1, K+1), for backtracing placements.
    """
    backend = resolve_backend(backend)
    _obs = obs.enabled()
    _t0 = obs.now_ns() if _obs else 0
    dp = jnp.full((T + 1, K + 1), jnp.inf, dtype=jnp.float32)
    dp = dp.at[:, 0].set(0.0)
    stages = [dp]
    for t_i, e_i in zip(t_items, e_items):
        if backend == "ref":
            dp = _ref_fold(dp, jnp.int32(t_i), jnp.float32(e_i))
        else:
            # t_i/e_i are traced operands here too (SMEM scalars in the
            # kernel): one compile per table shape, not per cost value
            dp = dp_space_update_pallas(
                dp, t_i=jnp.int32(t_i), e_i=jnp.float32(e_i), bk=bk,
                interpret=(backend == "pallas_interpret"))
        if return_stages:
            stages.append(dp)
    if _obs:
        # dispatch accounting keyed by the RESOLVED backend, so a trace
        # shows whether the kernel, interpreter or ref path actually ran
        obs.counter("kernels.knapsack_dp.dispatch", backend=backend)
        obs.observe("kernels.knapsack_dp.us",
                    (obs.now_ns() - _t0) / 1e3, backend=backend)
    if return_stages:
        return jnp.stack(stages)
    return dp
