"""Public op: per-cluster Algorithm-1 DP table, kernel- or ref-backed."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels.knapsack_dp.kernel import dp_space_update_pallas
from repro.kernels.knapsack_dp.ref import dp_space_update_ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


def knapsack_dp(t_items: Sequence[int], e_items: Sequence[float],
                T: int, K: int, *, backend: str = "auto",
                bk: int = 512) -> jnp.ndarray:
    """Build the (T+1, K+1) min-energy table for one cluster's spaces.

    backend: "auto" | "pallas" | "pallas_interpret" | "ref".
    """
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    dp = jnp.full((T + 1, K + 1), jnp.inf, dtype=jnp.float32)
    dp = dp.at[:, 0].set(0.0)
    for t_i, e_i in zip(t_items, e_items):
        if backend == "ref":
            dp = dp_space_update_ref(dp, int(t_i), float(e_i))
        else:
            dp = dp_space_update_pallas(
                dp, t_i=int(t_i), e_i=float(e_i), bk=bk,
                interpret=(backend == "pallas_interpret"))
    return dp
