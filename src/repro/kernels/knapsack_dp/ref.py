"""Pure-jnp oracle for the per-cluster knapsack DP table (Algorithm 1).

dp[t, k] = min energy placing exactly k weight-groups in the spaces seen so
far within time t (integer ticks). The recurrence over one space i is

    dp_i[t, k] = min(dp_{i-1}[t, k], dp_i[t - t_i, k - 1] + e_i)

which is sequential in t and vectorized over k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


def dp_space_update_ref(dp_prev: jnp.ndarray, t_i, e_i) -> jnp.ndarray:
    """Fold one storage space into the DP table.

    Args:
      dp_prev: (T+1, K+1) float32 table of the previous space.
      t_i:     integer tick cost per item in this space; a python int or
               a traced scalar (ops.py jits this fold with t_i/e_i as
               arguments so the compile cache is keyed on shape only).
      e_i:     energy per item in this space (python float or traced).

    Returns:
      (T+1, K+1) updated table.
    """
    T1, K1 = dp_prev.shape

    def body(t, dp):
        take = jnp.where(
            t >= t_i,
            jnp.concatenate([jnp.full((1,), INF),
                             jax.lax.dynamic_slice_in_dim(
                                 dp, jnp.maximum(t - t_i, 0), 1, axis=0
                             )[0, :-1] + jnp.float32(e_i)]),
            jnp.full((K1,), INF))
        row = jnp.minimum(dp[t], take)
        return dp.at[t].set(row)

    return jax.lax.fori_loop(0, T1, body, dp_prev)


def knapsack_dp_ref(t_items, e_items, T: int, K: int) -> jnp.ndarray:
    """Full Algorithm-1 table for one cluster: returns dp[n] of shape
    (T+1, K+1)."""
    dp = jnp.full((T + 1, K + 1), INF, dtype=jnp.float32)
    dp = dp.at[:, 0].set(0.0)
    for t_i, e_i in zip(t_items, e_items):
        dp = dp_space_update_ref(dp, int(t_i), float(e_i))
    return dp
