"""Pallas TPU kernel for the fused LUT pipeline (one launch per build).

The unfused dp path runs the ``knapsack_dp`` kernel per cluster and then
folds the gathered tables on the host (numpy ``combine_many``) - one
device<->host round-trip per build stage. This kernel keeps the whole
Algorithm-1 + Algorithm-2 pipeline resident: a single ``pallas_call``
walks the grid

    (v, c, i, p)  =  variant x cluster x space x K-panel,

row-major (sequential on TPU), so scratch persists across steps and acts
as the dataflow spine:

  * ``S``     (T+1, Kp)  rolling stage buffer: at step ``(c, i, p)``
               panels ``>= p`` still hold stage ``i-1``, panels ``< p``
               already hold stage ``i`` - exactly the knapsack kernel's
               panel chain, batched over clusters and variants;
  * ``carry`` (T+1, 1)   the k-1 carry column across K-panels (reset to
               +inf at ``p == 0``, i.e. per space);
  * ``G``     (Rp, Kp)   the current cluster's final table gathered at
               the consulted t-grid rows (filled panel-by-panel during
               the last space);
  * ``F``     (Rp, Kp)   Algorithm-2 fold accumulator across clusters;
  * ``A``     (C-2, Rp, K+1) argmin traces of the middle folds, for the
               in-kernel split backtrace.

Each ``(v, c, i, p)`` step seeds its stage-output block from the
previous stage (the k=0 base pattern when ``i == 0``, the ``S`` panel
otherwise) and runs the t-recurrence in place - reads of row ``t``
see the previous stage, reads of row ``t - t_i < t`` see the updated
rows, matching the knapsack kernel's separate in/out panels bit for bit.
At the last panel of the last space of each cluster the kernel folds
``G`` into ``F`` (``repro.core.multipool.minplus_fold_jnp`` - the same
function the ref backend jits), and at the last cluster it runs the
final k=K combine plus the one-hot argmin backtrace and emits the
per-variant ``min_e`` / ``splits`` outputs.

VMEM: the stage block + S + carry are (T+1)*(2*Kp+1)*4 B, G/F another
2*Rp*Kp*4 B (defaults T=2048, Kp=512, Rp<=72: ~8.7 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.multipool import backtrace_splits_jnp, minplus_fold_jnp

# the fold/splits outputs are per-variant (Rp, FOLD_LANES) blocks; only
# lane 0 of min_e and lanes < C of splits are meaningful (lane-width
# padding keeps the blocks TPU-tileable)
FOLD_LANES = 128


def _emit(fold_ref, splits_ref, min_e, splits, Rp: int, C: int) -> None:
    """Write the (Rp,) min-energy and (Rp, C) splits into the padded
    per-variant output blocks."""
    fold_ref[0] = jnp.broadcast_to(min_e[:, None], (Rp, FOLD_LANES))
    col = jax.lax.broadcasted_iota(jnp.int32, (Rp, FOLD_LANES), 1)
    out = jnp.full((Rp, FOLD_LANES), -1, jnp.int32)
    for c in range(C):
        out = jnp.where(col == c, splits[:, c:c + 1], out)
    splits_ref[0] = out


def _fused_kernel(t_ref, e_ref, rows_ref, stages_ref, fold_ref, splits_ref,
                  S, carry, F, G, A, *, T1: int, K1: int, bk: int,
                  C: int, n: int, Rp: int):
    v = pl.program_id(0)
    c = pl.program_id(1)
    i = pl.program_id(2)
    p = pl.program_id(3)
    P = pl.num_programs(3)
    off = pl.multiple_of(p * bk, bk)
    t_i = t_ref[v, c, i]
    e_i = e_ref[v, c, i]

    @pl.when(p == 0)
    def _reset_carry():
        carry[:, :] = jnp.full((T1, 1), float("inf"), jnp.float32)

    # seed this panel with the previous stage: the k=0 base pattern for
    # the first space, the S rolling buffer (stage i-1 at panels >= p,
    # not yet overwritten) afterwards
    @pl.when(i == 0)
    def _seed_base():
        col = jax.lax.broadcasted_iota(jnp.int32, (T1, bk), 1) + off
        stages_ref[0, 0, 0] = jnp.where(col == 0, 0.0,
                                        float("inf")).astype(jnp.float32)

    @pl.when(i > 0)
    def _seed_prev():
        stages_ref[0, 0, 0] = S[:, pl.ds(off, bk)]

    def body(t, _):
        row = stages_ref[0, 0, 0, t, :]        # prev stage: not yet written
        prev_t = jnp.maximum(t - t_i, 0)
        # dp_new[t, k] uses dp_new[t - t_i, k - 1]: rows < t are already
        # updated in place; carry holds the updated k-1 column of the
        # previous panel
        shifted = jnp.concatenate(
            [carry[prev_t, :], stages_ref[0, 0, 0, prev_t, :-1]])
        take = jnp.where(t >= t_i, shifted + e_i, float("inf"))
        stages_ref[0, 0, 0, t, :] = jnp.minimum(row, take)
        return 0

    jax.lax.fori_loop(0, T1, body, 0, unroll=False)

    new_panel = stages_ref[0, 0, 0]            # (T1, bk): now stage i
    carry[:, :] = new_panel[:, bk - 1:bk]
    S[:, pl.ds(off, bk)] = new_panel

    # last space of the cluster: gather the consulted t-grid rows of the
    # cluster's final table, panel by panel
    @pl.when(i == n - 1)
    def _gather_rows():
        def g_body(r, _):
            G[r, pl.ds(off, bk)] = new_panel[rows_ref[v, r], :]
            return 0
        jax.lax.fori_loop(0, Rp, g_body, 0, unroll=False)

    last = (i == n - 1) & (p == P - 1)

    if C == 1:
        @pl.when(last)
        def _combine_single():
            min_e = G[:, K1 - 1]
            feasible = jnp.isfinite(min_e)
            splits = jnp.where(feasible[:, None], jnp.int32(K1 - 1),
                               jnp.int32(-1))
            _emit(fold_ref, splits_ref, min_e, splits, Rp, 1)
        return

    @pl.when(last & (c == 0))
    def _fold_init():
        F[:, :] = G[:, :]

    if C > 2:
        @pl.when(last & (c > 0) & (c < C - 1))
        def _fold_middle():
            out, arg = minplus_fold_jnp(F[:, :K1], G[:, :K1])
            F[:, :K1] = out
            A[pl.ds(c - 1, 1), :, :] = arg[None]

    @pl.when(last & (c == C - 1))
    def _fold_final():
        # final combine at k = K only: cand[r, i] = F[r, i] + E[r, K-i]
        cand = F[:, :K1] + G[:, :K1][:, ::-1]
        i_opt = jnp.argmin(cand, axis=1).astype(jnp.int32)
        min_e = jnp.min(cand, axis=1)
        feasible = jnp.isfinite(min_e)
        args = [A[j] for j in range(C - 2)]
        splits = backtrace_splits_jnp(args, i_opt, feasible, K1 - 1, C)
        _emit(fold_ref, splits_ref, min_e, splits, Rp, C)


@functools.partial(jax.jit, static_argnames=("T", "K", "bk", "interpret"))
def lut_pipeline_pallas(t_items: jnp.ndarray, e_items: jnp.ndarray,
                        rows: jnp.ndarray, *, T: int, K: int,
                        bk: int = 512, interpret: bool = False):
    """Fused DP + combine in one ``pallas_call`` (see module docstring).

    Same contract as :func:`repro.kernels.lut_pipeline.ref.lut_pipeline_ref`:
    ``t_items``/``e_items`` (V, C, n) inert-padded costs, ``rows`` (V, R)
    consulted tick rows; returns ``(stages, min_e, splits)`` with the
    k=0 base stage omitted.
    """
    V, C, n = t_items.shape
    R = rows.shape[1]
    T1, K1 = T + 1, K + 1
    if C > FOLD_LANES:
        raise ValueError(f"cluster count {C} exceeds the splits-output "
                         f"lane width {FOLD_LANES}")
    Kp = K1 + ((-K1) % bk)
    P = Kp // bk
    Rp = R + ((-R) % 8)
    rows_p = jnp.pad(rows, ((0, 0), (0, Rp - R)))

    kernel = functools.partial(_fused_kernel, T1=T1, K1=K1, bk=bk, C=C,
                               n=n, Rp=Rp)

    def smem(arr):
        return pl.BlockSpec(arr.shape,
                            lambda v, c, i, p: (0,) * arr.ndim,
                            memory_space=pltpu.SMEM)

    t_arr = t_items.astype(jnp.int32)
    e_arr = e_items.astype(jnp.float32)
    stages, fold, splits = pl.pallas_call(
        kernel,
        grid=(V, C, n, P),
        in_specs=[smem(t_arr), smem(e_arr), smem(rows_p)],
        out_specs=(
            pl.BlockSpec((1, 1, 1, T1, bk),
                         lambda v, c, i, p: (v, c, i, 0, p)),
            pl.BlockSpec((1, Rp, FOLD_LANES), lambda v, c, i, p: (v, 0, 0)),
            pl.BlockSpec((1, Rp, FOLD_LANES), lambda v, c, i, p: (v, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((V, C, n, T1, Kp), jnp.float32),
            jax.ShapeDtypeStruct((V, Rp, FOLD_LANES), jnp.float32),
            jax.ShapeDtypeStruct((V, Rp, FOLD_LANES), jnp.int32),
        ),
        scratch_shapes=[
            pltpu.VMEM((T1, Kp), jnp.float32),           # S
            pltpu.VMEM((T1, 1), jnp.float32),            # carry
            pltpu.VMEM((Rp, Kp), jnp.float32),           # F
            pltpu.VMEM((Rp, Kp), jnp.float32),           # G
            pltpu.VMEM((max(C - 2, 1), Rp, K1), jnp.int32),  # A
        ],
        interpret=interpret,
    )(t_arr, e_arr, rows_p)
    return stages[..., :K1], fold[:, :R, 0], splits[:, :R, :C]
