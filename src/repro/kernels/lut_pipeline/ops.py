"""Public op: the fused on-device LUT pipeline, kernel- or ref-backed.

This is the production build engine behind ``build_lut(method="dp",
batched=True)`` (repro.core.placement) and the clock-grid batched
``build_lut_grid``: per-cluster Algorithm-1 stage tables, the consulted
t-grid row gather, and the Algorithm-2 min-plus combine with argmin
backtrace, all in one device launch per build - instead of one
``knapsack_dp`` dispatch per cluster plus a host numpy fold per build.
The backends are

  * ``pallas``           - the fused TPU kernel (kernel.py), one
    ``pallas_call`` over the (variant, cluster, space, K-panel) grid,
  * ``pallas_interpret`` - the same kernel under the Pallas interpreter,
    so the fused path (including the K-panel carry chain) is exercised
    end-to-end on CPU runners (CI),
  * ``ref``              - the jitted pure-jnp oracle (ref.py), the CPU
    production backend.

``backend="auto"`` resolves to ``pallas`` on TPU and ``ref`` elsewhere;
the ``REPRO_LUT_BACKEND`` environment variable overrides the auto
choice. All backends return byte-identical float32 tables and identical
integer splits (asserted by tests/test_lut_pipeline.py), so backend
choice never changes a LUT entry.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.lut_pipeline.kernel import lut_pipeline_pallas
from repro.kernels.lut_pipeline.ref import lut_pipeline_ref

BACKEND_ENV = "REPRO_LUT_BACKEND"

BACKENDS = ("ref", "pallas", "pallas_interpret")


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


def resolve_backend(backend: str = "auto") -> str:
    """Resolve ``auto`` to a concrete backend (env override wins) and
    validate the result, so a typo'd env value fails with the valid
    names instead of an opaque lowering error."""
    if backend == "auto":
        backend = (os.environ.get(BACKEND_ENV)
                   or ("pallas" if _on_tpu() else "ref"))
    if backend not in BACKENDS:
        raise ValueError(f"unknown lut_pipeline backend {backend!r}; "
                         f"one of {BACKENDS} (or 'auto', env var "
                         f"{BACKEND_ENV})")
    return backend


def lut_build(t_items, e_items, T: int, K: int, rows, *,
              backend: str = "auto", bk: int = 512):
    """Fused Algorithm-1 + Algorithm-2 evaluation, batched over variants.

    Args:
      t_items: (V, C, n) per-variant/cluster/space integer tick costs.
        Ragged clusters must be inert-padded with ``(t=1, e=+inf)``; an
        infinite-cost space folds to a bitwise copy of the previous
        stage, so padding changes no byte of any result (and the
        placement backtrace walks through padded stages via its
        carry branch).
      e_items: (V, C, n) per-space energies (pad ``+inf``).
      T, K: tick horizon / weight-group count; tables are (T+1, K+1).
      rows: (R,) or (V, R) consulted t-grid tick rows, ``0 <= row <= T``.
      backend: "auto" | "pallas" | "pallas_interpret" | "ref".
      bk: K-panel width of the pallas kernel.

    Returns:
      stages: (V, C, n+1, T+1, K+1) float32 per-space DP stage tables,
        stage 0 being the k=0 base - the same layout
        ``knapsack_dp(..., return_stages=True)`` yields per cluster,
        ready for ``placement.backtrace_tables``.
      min_e:  (V, R) float32 min total energy per consulted row.
      splits: (V, R, C) int32 optimal per-cluster group counts
        (-1 on infeasible rows), bit-matching the numpy
        ``combine_many`` fold of the same tables.
    """
    backend = resolve_backend(backend)
    t = jnp.asarray(t_items, jnp.int32)
    e = jnp.asarray(e_items, jnp.float32)
    if t.ndim != 3 or e.shape != t.shape:
        raise ValueError(f"t_items/e_items must both be (V, C, n), got "
                         f"{t.shape} and {e.shape}")
    V = t.shape[0]
    r = jnp.asarray(rows, jnp.int32)
    if r.ndim == 1:
        r = jnp.broadcast_to(r[None, :], (V, r.shape[0]))
    _obs = obs.enabled()
    _t0 = obs.now_ns() if _obs else 0
    if backend == "ref":
        stages, min_e, splits = lut_pipeline_ref(t, e, r, T=T, K=K)
    else:
        stages, min_e, splits = lut_pipeline_pallas(
            t, e, r, T=T, K=K, bk=bk,
            interpret=(backend == "pallas_interpret"))
    base = jnp.full((V, t.shape[1], 1, T + 1, K + 1), jnp.inf, jnp.float32)
    base = base.at[..., 0].set(0.0)
    stages = jnp.concatenate([base, stages], axis=2)
    if _obs:
        # dispatch accounting keyed by the RESOLVED backend, so a trace
        # shows whether the kernel, interpreter or ref path actually ran
        obs.counter("kernels.lut_pipeline.dispatch", backend=backend)
        obs.observe("kernels.lut_pipeline.us",
                    (obs.now_ns() - _t0) / 1e3, backend=backend)
    return stages, min_e, splits
