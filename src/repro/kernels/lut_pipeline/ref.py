"""Jitted pure-jnp oracle for the fused LUT pipeline.

One call evaluates, for every variant ``v`` of a batched build:

  1. the per-cluster Algorithm-1 DP stage tables (the same
     ``dp_space_update_ref`` fold the ``knapsack_dp`` op jits, so the
     stage-table float bits match the unfused op exactly),
  2. the row gather of each cluster's final table at the consulted
     t-grid tick rows,
  3. the Algorithm-2 min-plus combine with argmin backtrace
     (``repro.core.multipool.combine_rows_jnp`` - the jax twin of the
     numpy host fold, same candidates in the same order).

Ragged clusters are inert-padded by the caller (``t=1, e=+inf``): an
infinite-cost space folds to a bitwise copy of the previous stage, so
padding changes no byte of any table or combine result.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.multipool import combine_rows_jnp
from repro.kernels.knapsack_dp.ref import dp_space_update_ref

INF = jnp.float32(jnp.inf)


@functools.partial(jax.jit, static_argnames=("T", "K"))
def lut_pipeline_ref(t_items: jnp.ndarray, e_items: jnp.ndarray,
                     rows: jnp.ndarray, *, T: int, K: int):
    """Fused DP + combine, batched over variants.

    Args:
      t_items: (V, C, n) int32 per-space tick costs (inert-padded).
      e_items: (V, C, n) float32 per-space energies (pad ``+inf``).
      rows:    (V, R) int32 consulted t-tick rows, ``0 <= row <= T``.
      T, K: static tick horizon / group count (tables are (T+1, K+1)).

    Returns:
      stages: (V, C, n, T+1, K+1) float32 per-space DP tables (the k=0
        base stage is NOT included; ops.py prepends it).
      min_e:  (V, R) float32 minimum total energy per consulted row.
      splits: (V, R, C) int32 per-cluster group counts (-1 infeasible).
    """
    V, C, n = t_items.shape
    base = jnp.full((T + 1, K + 1), INF, jnp.float32).at[:, 0].set(0.0)
    stages_out, min_e_out, splits_out = [], [], []
    for v in range(V):
        finals = []
        stages_v = []
        for c in range(C):
            dp = base
            stages_c = []
            for i in range(n):
                dp = dp_space_update_ref(dp, t_items[v, c, i],
                                         e_items[v, c, i])
                stages_c.append(dp)
            stages_v.append(jnp.stack(stages_c))
            finals.append(jnp.take(dp, rows[v], axis=0))
        min_e, splits = combine_rows_jnp(jnp.stack(finals))
        stages_out.append(jnp.stack(stages_v))
        min_e_out.append(min_e)
        splits_out.append(splits)
    return (jnp.stack(stages_out), jnp.stack(min_e_out),
            jnp.stack(splits_out))
