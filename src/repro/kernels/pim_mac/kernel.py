"""Pallas TPU kernel: blocked W8A8 matmul with fused dequantization.

TPU-native adaptation of the paper's PIM MAC datapath (DESIGN.md SS.3):
INT8 weight residency is the "MRAM tier" - half the HBM traffic of bf16 -
and the MAC accumulates in int32 like the PIM PE, dequantizing once per
output tile in the epilogue.

Tiling: grid = (M/bm, N/bn, K/bk) with K innermost (sequential reduction).
Per grid step the kernel holds an (bm, bk) x-tile, a (bk, bn) w-tile and an
(bm, bn) int32 accumulator in VMEM. Block sizes default to MXU-aligned
(128x128x128); VMEM footprint = bm*bk + bk*bn (int8) + bm*bn*4 (acc)
= 16 kB + 16 kB + 64 kB at defaults - far under the ~16 MB/core budget, so
larger bn/bk can be chosen by the autotune sweep in benchmarks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pim_mac_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *,
                    k_steps: int, out_dtype):
    """One (i, j, k) grid step: acc += x_tile @ w_tile; epilogue on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 x int8 -> int32 runs on the MXU with int8 inputs on TPU.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        sx = sx_ref[...].astype(jnp.float32)      # (bm, 1)
        sw = sw_ref[...].astype(jnp.float32)      # (1, bn)
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * sx * sw
                      ).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def pim_matmul_pallas(x_i8: jnp.ndarray, w_i8: jnp.ndarray,
                      scale_x: jnp.ndarray, scale_w: jnp.ndarray, *,
                      bm: int = 128, bn: int = 128, bk: int = 128,
                      out_dtype=jnp.float32,
                      interpret: bool = False) -> jnp.ndarray:
    """Blocked W8A8 matmul. Shapes must be multiples of the block sizes
    (the ops.py wrapper pads); ``scale_x``: (M,), ``scale_w``: (N,)."""
    M, K = x_i8.shape
    K2, N = w_i8.shape
    assert K == K2, (x_i8.shape, w_i8.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    k_steps = K // bk
    sx = scale_x.reshape(M, 1).astype(jnp.float32)
    sw = scale_w.reshape(1, N).astype(jnp.float32)

    return pl.pallas_call(
        functools.partial(_pim_mac_kernel, k_steps=k_steps,
                          out_dtype=out_dtype),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # x tile
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # w tile
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),    # row scales
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),    # col scales
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],     # VMEM acc
        interpret=interpret,
    )(x_i8, w_i8, sx, sw)
