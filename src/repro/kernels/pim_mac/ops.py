"""Public op wrapper for the PIM-MAC kernel.

``pim_matmul`` pads arbitrary shapes to block multiples, dispatches to the
Pallas kernel on TPU (or ``interpret=True`` for CPU validation) and to the
pure-jnp oracle elsewhere - the math is identical, so models built on this
op lower cleanly in the CPU dry-run while targeting the kernel on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.pim_mac.kernel import pim_matmul_pallas
from repro.kernels.pim_mac.ref import pim_matmul_ref


def _pad_to(x: jnp.ndarray, mult0: int, mult1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


def pim_matmul(x_i8: jnp.ndarray, w_i8: jnp.ndarray,
               scale_x: jnp.ndarray, scale_w: jnp.ndarray, *,
               bm: int = 128, bn: int = 128, bk: int = 128,
               out_dtype=jnp.float32, backend: str = "auto") -> jnp.ndarray:
    """W8A8 matmul with per-row/col scales; any (M, K) x (K, N) shapes.

    backend: "auto" (pallas on TPU, ref elsewhere), "pallas",
             "pallas_interpret" (kernel body on CPU), or "ref".

    Backend resolution and dispatch accounting stay OUTSIDE the jit so
    every call is counted (the jitted body only runs at trace time);
    the resolved backend is a static argname, so the compile cache is
    unchanged.
    """
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if obs.enabled():
        obs.counter("kernels.pim_mac.dispatch", backend=backend)
    return _pim_matmul_impl(x_i8, w_i8, scale_x, scale_w, bm=bm, bn=bn,
                            bk=bk, out_dtype=out_dtype, backend=backend)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "backend"))
def _pim_matmul_impl(x_i8: jnp.ndarray, w_i8: jnp.ndarray,
                     scale_x: jnp.ndarray, scale_w: jnp.ndarray, *,
                     bm: int, bn: int, bk: int, out_dtype,
                     backend: str) -> jnp.ndarray:
    M, K = x_i8.shape
    _, N = w_i8.shape
    scale_x = jnp.broadcast_to(jnp.asarray(scale_x, jnp.float32).reshape(-1),
                               (M,))
    scale_w = jnp.broadcast_to(jnp.asarray(scale_w, jnp.float32).reshape(-1),
                               (N,))
    if backend == "ref":
        return pim_matmul_ref(x_i8, w_i8, scale_x, scale_w, out_dtype)

    interpret = backend == "pallas_interpret"
    xp = _pad_to(x_i8, bm, bk)
    wp = _pad_to(w_i8, bk, bn)
    sxp = jnp.pad(scale_x, (0, (-M) % bm))
    swp = jnp.pad(scale_w, (0, (-N) % bn))
    out = pim_matmul_pallas(xp, wp, sxp, swp, bm=bm, bn=bn, bk=bk,
                            out_dtype=out_dtype, interpret=interpret)
    return out[:M, :N]
