"""Pure-jnp oracle for the PIM-MAC kernel (W8A8 -> int32 -> scaled float).

This is the TPU analogue of the paper's PIM MAC path: INT8 weights are the
"MRAM tier" residency format (half the HBM bytes of bf16), and the MAC
accumulates in int32 exactly as the PIM PE does.
"""
from __future__ import annotations

import jax.numpy as jnp


def pim_matmul_ref(x_i8: jnp.ndarray, w_i8: jnp.ndarray,
                   scale_x: jnp.ndarray, scale_w: jnp.ndarray,
                   out_dtype=jnp.float32) -> jnp.ndarray:
    """``(M,K)i8 @ (K,N)i8 -> (M,N)`` with per-row/per-col dequant scales.

    Args:
      x_i8:     (M, K) int8 activations.
      w_i8:     (K, N) int8 weights.
      scale_x:  scalar or (M,) per-row activation scale.
      scale_w:  scalar or (N,) per-column weight scale.
    """
    acc = jnp.dot(x_i8.astype(jnp.int32), w_i8.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    sx = jnp.asarray(scale_x, jnp.float32)
    sw = jnp.asarray(scale_w, jnp.float32)
    if sx.ndim == 1:
        sx = sx[:, None]
    if sw.ndim == 1:
        sw = sw[None, :]
    return (acc.astype(jnp.float32) * sx * sw).astype(out_dtype)
