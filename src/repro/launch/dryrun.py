import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
we build the production mesh (16x16 single pod / 2x16x16 multi-pod), attach
NamedShardings to abstract params/optimizer/batch pytrees, and require
``jax.jit(step).lower(...).compile()`` to succeed. ``memory_analysis()``
(fits per chip?) and ``cost_analysis()`` (FLOPs/bytes) plus the collective
bytes parsed from the compiled HLO are dumped as JSON for
EXPERIMENTS.md SS.Dry-run / SS.Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single,multi --out experiments/dryrun
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.optim.adamw import OptimizerConfig, make_optimizer
from repro.parallel import sharding as sh
from repro.train.step import (default_optimizer_kind,
                              default_train_memory_plan, make_train_step)

from repro.launch.hloparse import collective_bytes, while_summary


def _flops_bytes(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {"flops": float(ca.get("flops", -1.0)),
                "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
                "raw_keys": sorted(k for k in ca if "bytes accessed" in k
                                   or k == "flops")[:8]}
    except Exception as e:          # pragma: no cover
        return {"error": repr(e)}


def _memory(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        keys = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
        return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    except Exception as e:          # pragma: no cover
        return {"error": repr(e)}


def _substrate_summary(cfg, name: str) -> dict:
    """Serving-substrate coherence check for one arch: the registry entry
    must map the config to a model spec and yield a feasible placement LUT
    at the default slice (the placement analogue of "does it compile")."""
    from repro import api
    try:
        sub = api.substrate(name)
        model = sub.model_spec(cfg)
        t_ns = sub.default_t_slice_ns(model)
        lut = sub.build_lut(model, t_slice_ns=t_ns)
        feasible = [e for e in lut.entries if e.feasible]
        return {"substrate": name, "model_spec": model.name,
                "n_params": model.n_params,
                "t_slice_ms": round(t_ns / 1e6, 6),
                "lut_entries": len(lut.entries),
                "lut_feasible": len(feasible),
                "min_feasible_t_ms": (round(lut.min_feasible_t_ns / 1e6, 6)
                                      if feasible else None)}
    except Exception as e:
        return {"substrate": name, "error": repr(e)}


def lower_cell(arch: str, shape: str, mesh, *, microbatches: int = 8):
    """Build and lower one cell; returns (lowered, meta)."""
    cfg = sp.dryrun_config(get_config(arch), mesh)
    seq, batch, kind = sp.SHAPES[shape]
    ok, why = sp.cell_is_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": why}

    params_abs = sp.abstract_params(
        cfg, serve_dtype=None if kind == "train" else jnp.bfloat16)
    inference = (kind != "train"
                 and sh.inference_fits_tp_only(params_abs, mesh))
    pshard = sh.params_shardings(params_abs, mesh, inference=inference)
    meta = {"arch": arch, "shape": shape, "kind": kind,
            "seq": seq, "batch": batch,
            "mesh": dict(mesh.shape), "tp_only_params": inference,
            "n_params": int(sum(x.size for x in jax.tree.leaves(params_abs)))}

    if kind == "train":
        # ZeRO-1 mixed precision when the bf16 compute params are cheap to
        # replicate across data ranks (<= 2 GiB/dev): kills per-microbatch
        # FSDP weight gathers (SS.Perf iter 3). Bigger models stay FSDP -
        # they are memory-bound and their collectives are activation ARs.
        zero1 = sh.inference_fits_tp_only(
            sp.abstract_params(cfg, serve_dtype=jnp.bfloat16), mesh,
            budget_bytes=2 * 2 ** 30)
        if zero1:
            params_abs = sp.abstract_params(cfg, serve_dtype=jnp.bfloat16)
            pshard = sh.params_shardings(params_abs, mesh, inference=True)
            opt_cfg = OptimizerConfig(kind="adamw_mp")
        else:
            opt_cfg = OptimizerConfig(kind=default_optimizer_kind(cfg))
        opt = make_optimizer(opt_cfg)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        oshard = sh.params_shardings_like(opt_abs, params_abs, pshard, mesh)
        batch_abs = sp.train_batch_specs(cfg, seq, batch)
        bshard = sh.batch_shardings(batch_abs, mesh)
        plan = default_train_memory_plan(cfg, batch)
        step = make_train_step(cfg, opt, **plan)
        meta["microbatches"] = plan["num_microbatches"]
        meta["accum_dtype"] = str(plan["accum_dtype"].__name__)
        meta["optimizer"] = opt_cfg.kind
        meta["zero1"] = zero1
        with mesh:
            jitted = jax.jit(step,
                             in_shardings=(pshard, oshard, bshard),
                             out_shardings=(pshard, oshard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        return lowered, meta

    if kind == "prefill":
        batch_abs = sp.train_batch_specs(cfg, seq, batch)
        bshard = sh.batch_shardings(batch_abs, mesh)

        def prefill_step(params, b):
            # serving prefill: process the prompt, sample from the LAST
            # position only (materializing (B, S, vocab) logits at 32k x
            # 256k vocab would be a 500 GiB tensor no server ever builds)
            h, _ = lm.forward_hidden(params, cfg, b["tokens"],
                                     prefix_embeds=b.get("prefix_embeds"),
                                     enc_frames=b.get("enc_frames"))
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["lm_head"]).astype(cfg.dtype)
            return h[:, -1, :] @ head

        with mesh:
            jitted = jax.jit(prefill_step, in_shardings=(pshard, bshard),
                             out_shardings=None)
            lowered = jitted.lower(params_abs, batch_abs)
        return lowered, meta

    # decode
    state_abs = sp.abstract_decode_state(cfg, batch, seq)
    sshard = sh.decode_state_shardings(state_abs, mesh)
    tok_abs, pos_abs = sp.decode_token_specs(batch)

    def serve_step(params, state, toks, pos):
        return lm.decode_step(params, cfg, state, toks, pos)

    with mesh:
        jitted = jax.jit(serve_step,
                         in_shardings=(pshard, sshard, None, None),
                         out_shardings=(None, sshard),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_abs, state_abs, tok_abs, pos_abs)
    return lowered, meta


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: Path,
             force: bool = False,
             substrate: str = "tpu-pool,gpu-pool") -> dict:
    tag = f"{arch}__{shape}__{mesh_kind}"
    out_file = out_dir / f"{tag}.json"
    if out_file.exists() and not force:
        return json.loads(out_file.read_text())
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    rec = {"cell": tag}
    try:
        lowered, meta = lower_cell(arch, shape, mesh)
        rec.update(meta)
        if substrate and substrate != "none" and meta.get("kind") == "decode":
            summaries = [_substrate_summary(get_config(arch), s)
                         for s in substrate.split(",") if s]
            # single-substrate key kept for older result readers
            rec["substrate"] = summaries[0]
            rec["substrates"] = summaries
        if lowered is None:
            rec["status"] = "skipped"
        else:
            compiled = lowered.compile()
            rec["status"] = "ok"
            rec["compile_s"] = round(time.time() - t0, 1)
            rec["memory"] = _memory(compiled)
            rec["cost"] = _flops_bytes(compiled)
            hlo = compiled.as_text()
            rec["collectives"] = collective_bytes(hlo)
            rec["while_trips"] = while_summary(hlo)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = repr(e)
        rec["traceback"] = traceback.format_exc()[-4000:]
    out_dir.mkdir(parents=True, exist_ok=True)
    out_file.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--substrate", default="tpu-pool,gpu-pool",
                    help="comma-separated serving substrates to sanity-"
                         "check per decode cell ('none' to skip): each "
                         "must map the arch config to a model spec and "
                         "yield a feasible placement LUT")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(sp.SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")
    out_dir = Path(args.out)

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, out_dir,
                               force=args.force, substrate=args.substrate)
                status = rec.get("status")
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_err += status == "error"
                line = f"{rec['cell']:55s} {status}"
                if status == "ok":
                    mem = rec.get("memory", {})
                    per_dev = (mem.get("argument_size_in_bytes", 0)
                               + mem.get("temp_size_in_bytes", 0))
                    line += (f" compile={rec.get('compile_s')}s"
                             f" mem/dev={per_dev/2**30:.2f}GiB"
                             f" flops={rec.get('cost', {}).get('flops')}")
                elif status == "error":
                    line += f"  {rec.get('error', '')[:120]}"
                print(line, flush=True)
    print(f"\nok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
