"""Fleet serving launcher: ``python -m repro.launch.fleet``.

Runs a trace-driven multi-engine serving fleet: N HH-PIM serve engines
(TPU parameterization), per-engine load forecasting driving proactive
weight migration, SLO-aware routing with optional admission control.

    python -m repro.launch.fleet --workload mmpp --engines 2 --requests 32
    python -m repro.launch.fleet --substrate gpu-pool --dvfs-controller ...
    python -m repro.launch.fleet --substrate cxl-tier-3 \\
        --lut-cache ckpt/luts.json ...                    # warm-start
    python -m repro.launch.fleet --trace --flight-recorder ...  # DESIGN SS.8
    python -m repro.launch.fleet --cells 16 --engines 128 \\
        --autoscale --max-engines 512 --no-decode          # DESIGN SS.9

``--cells N`` switches to the two-level hierarchical fleet
(:mod:`repro.fleet.hierarchy`): ``--engines`` becomes the total initial
engine count split evenly across N cells, the global tier routes by
queue-aware per-class scoring, and ``--autoscale`` attaches the cell
autoscaler (``--max-engines`` caps the total; scale-ups are served from
placement-compiler warm starts, so the ``lut-cache:`` line must report
0 builds on a warm run). The hierarchical path is analytic-only.

``--trace [PATH]`` turns on the observability layer (repro.obs) and
writes a Perfetto-loadable ``trace.json`` plus a ``metrics.json``
snapshot after the run; ``--flight-recorder [PATH]`` arms the SLO-breach
flight recorder (ring buffer of per-slice fleet state, dumped as JSON
when the running deadline-miss rate crosses ``--miss-threshold``).

With ``--decode`` (default on the flat path) every worker carries a real
``HeteroServeEngine``: each slice's placement is applied as an actual
weight re-tiering and tokens are decoded through the tiered model on CPU.
``--no-decode`` runs the analytic scheduler/energy path only (fast; what
``benchmarks/fleet_bench.py`` sweeps).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro import api, obs
from repro.fleet import make_trace, summarize
from repro.fleet.forecast import FORECASTERS
from repro.fleet.hierarchy import CELL_POLICIES
from repro.fleet.router import POLICIES
from repro.fleet.traces import TRACES


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workload", default="mmpp",
                    help=f"arrival trace: one of {sorted(TRACES)} or a "
                         f"case* scenario (default mmpp)")
    ap.add_argument("--trace", nargs="?", const="trace.json", default=None,
                    metavar="PATH",
                    help="enable structured tracing; write Chrome "
                         "trace-event JSON to PATH (default trace.json, "
                         "with a metrics.json snapshot alongside)")
    ap.add_argument("--flight-recorder", nargs="?", const="flight.json",
                    default=None, metavar="PATH",
                    help="arm the SLO-breach flight recorder; dump the "
                         "last --flight-capacity slice frames to PATH "
                         "when the running deadline-miss rate crosses "
                         "--miss-threshold")
    ap.add_argument("--flight-capacity", type=int, default=32)
    ap.add_argument("--miss-threshold", type=float, default=0.3,
                    help="flight-recorder deadline-miss-rate trigger")
    ap.add_argument("--engines", type=int, default=2,
                    help="engine count (with --cells: total across cells)")
    ap.add_argument("--cells", type=int, default=None, metavar="N",
                    help="hierarchical fleet with N cells (two-level "
                         "router + per-class SLO admission; DESIGN SS.9)")
    ap.add_argument("--autoscale", action="store_true",
                    help="attach the cell autoscaler (requires --cells)")
    ap.add_argument("--max-engines", type=int, default=None,
                    help="autoscale ceiling, total across cells "
                         "(default: --engines, i.e. no growth)")
    ap.add_argument("--cell-policy", default="least_loaded",
                    choices=CELL_POLICIES,
                    help="engine selection inside a cell")
    ap.add_argument("--requests", type=int, default=None,
                    help="total request budget (truncates the trace)")
    ap.add_argument("--steps", type=int, default=25,
                    help="number of trace time slices")
    ap.add_argument("--forecaster", default="ewma",
                    choices=sorted(FORECASTERS))
    ap.add_argument("--policy", default="slo", choices=POLICIES)
    ap.add_argument("--margin", type=float, default=1.0,
                    help="forecast over-provisioning factor")
    ap.add_argument("--admission-limit", type=int, default=None,
                    help="max queued tasks per engine before rejecting "
                         "(flat fleet; --cells admits by expected wait)")
    ap.add_argument("--substrate", default=None,
                    help=f"one of {api.available_substrates()} "
                         f"(default tpu-pool; --mixed => tpu-pool-mixed)")
    ap.add_argument("--solver", default=None,
                    help=f"placement solver, one of {sorted(api.SOLVERS)}")
    ap.add_argument("--mixed", action="store_true",
                    help="heterogeneous pool: odd engines get half chips")
    ap.add_argument("--dvfs-controller", type=int, nargs="?", const=5,
                    default=None, metavar="N",
                    help="solve the DVFS clock online: pick the energy-"
                         "minimal (placement, clock) pair per slice over "
                         "an N-point TechModel grid (default 5; gpu-pool "
                         "and cxl-tier substrates, flat fleet path). The "
                         "chosen clock prints per slice (clk column) and "
                         "in the dvfs-controller: summary")
    ap.add_argument("--tokens-per-task", type=int, default=2)
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode", dest="decode", action="store_true",
                    default=True)
    ap.add_argument("--no-decode", dest="decode", action="store_false")
    ap.add_argument("--lut-cache", default=None, metavar="PATH",
                    help="warm-start: load the placement-compiler LUT "
                         "cache from PATH when it exists and save it back "
                         "after the run (serialize next to checkpoints so "
                         "a restarted fleet skips bring-up compiles)")
    ap.add_argument("--json", default=None,
                    help="write the summary to this path as JSON")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.autoscale and args.cells is None:
        raise SystemExit("--autoscale requires --cells")

    obs_on = args.trace is not None or args.flight_recorder is not None
    if obs_on:
        obs.reset()
        rec = None
        if args.flight_recorder is not None:
            rec = obs.FlightRecorder(
                capacity=args.flight_capacity,
                miss_rate_threshold=args.miss_threshold,
                path=args.flight_recorder)
        obs.enable(flight_recorder=rec)

    trace = make_trace(args.workload, n_slices=args.steps, seed=args.seed)
    if args.requests is not None:
        trace = trace.truncated(args.requests)

    if args.substrate and args.mixed \
            and not args.substrate.endswith("-mixed"):
        raise SystemExit(
            f"--mixed conflicts with --substrate {args.substrate}; "
            f"use a *-mixed substrate such as tpu-pool-mixed or "
            f"gpu-pool-mixed (or drop --mixed)")
    substrate = args.substrate or ("tpu-pool-mixed" if args.mixed
                                   else "tpu-pool")
    over = {"solver": args.solver} if args.solver else {}
    if args.dvfs_controller is not None:
        if args.cells is not None:
            raise SystemExit("--dvfs-controller runs on the flat fleet "
                             "path; drop --cells")
        if api.substrate(substrate, **over).tech_model() is None:
            raise SystemExit(
                f"--dvfs-controller needs a substrate with a registered "
                f"TechModel (gpu-pool / cxl-tier families); "
                f"{substrate} has none")
    if args.decode and args.cells is not None:
        if not args.quiet:
            print("hierarchical fleets run the analytic path only; "
                  "running as --no-decode")
        args.decode = False
    if args.decode and not api.substrate(substrate).supports_decode:
        print(f"substrate {substrate} is accounting-only (no functional "
              f"decode engine); running as --no-decode")
        args.decode = False

    params = cfg = None
    if args.decode:
        import jax
        from repro.configs import canonical, get_smoke_config
        from repro.models import lm
        cfg = get_smoke_config(args.arch)
        params = lm.init_lm(jax.random.PRNGKey(args.seed), cfg)
        print(f"arch={canonical(args.arch)} ({cfg.n_layers}L "
              f"d={cfg.d_model}, reduced config)")

    pc = api.compiler()
    if args.lut_cache:
        n = pc.load(args.lut_cache)
        if n:
            print(f"warm-start: loaded {n} cached LUTs from "
                  f"{args.lut_cache}")

    hier = None
    if args.cells is not None:
        per_cell = max(args.engines // args.cells, 1)
        max_per_cell = (per_cell if args.max_engines is None
                        else max(args.max_engines // args.cells, per_cell))
        hier = api.hierarchical_fleet(
            substrate, cfg, n_cells=args.cells,
            engines_per_cell=per_cell, forecaster=args.forecaster,
            cell_policy=args.cell_policy,
            autoscale=args.autoscale, max_engines=max_per_cell,
            tokens_per_task=args.tokens_per_task,
            forecast_margin=args.margin, compiler=pc, seed=args.seed,
            **over)
        n_engines = hier.n_engines
        T_us = hier.cells[0].t_slice_ns / 1e3
        print(f"fleet: {args.cells} cells x {per_cell} engines "
              f"({n_engines} total) on {substrate}, "
              f"cell-policy={args.cell_policy}, "
              f"autoscale={'on' if args.autoscale else 'off'}"
              f"{f' (ceiling {max_per_cell * args.cells})' if args.autoscale else ''}, "
              f"forecaster={args.forecaster}, t_slice={T_us:.2f} us, "
              f"trace={trace.name} ({trace.total} requests / "
              f"{len(trace)} slices, peak {trace.peak}/slice)")

        def cb(s, n_arr, done, cells):
            if args.quiet:
                return
            bl = "/".join(str(c.backlog) for c in cells)
            eng = "/".join(str(c.n_active) for c in cells)
            print(f"  slice {s:3d} arrivals {n_arr:4d} done "
                  f"{len(done):4d} backlog {bl} engines {eng}")

        res = hier.run(trace, verbose_cb=cb)
        s = summarize(res)
    else:
        fleet = api.fleet(
            substrate, cfg, n_engines=args.engines,
            forecaster=args.forecaster, policy=args.policy,
            tokens_per_task=args.tokens_per_task,
            admission_limit=args.admission_limit,
            forecast_margin=args.margin, params=params,
            decode=args.decode, compiler=pc,
            dvfs=args.dvfs_controller, **over)

        T_us = fleet.workers[0].t_slice_ns / 1e3
        dvfs_on = args.dvfs_controller is not None
        grid = fleet.workers[0].sched.dvfs.clocks if dvfs_on else ()
        print(f"fleet: {args.engines} engines on {substrate}"
              f", policy={args.policy}, forecaster={args.forecaster}, "
              f"t_slice={T_us:.2f} us, trace={trace.name} "
              f"({trace.total} requests / {len(trace)} slices, "
              f"peak {trace.peak}/slice)"
              + (f", dvfs-grid=[{'/'.join(f'{c:.2f}' for c in grid)}]"
                 if dvfs_on else ""))

        def cb(s, n_arr, done, workers):
            if args.quiet:
                return
            bl = "/".join(str(len(w.backlog)) for w in workers)
            mig = "/".join(
                "y" if (w.reports and w.reports[-1].moved_weights) else "."
                for w in workers)
            line = (f"  slice {s:3d} arrivals {n_arr:3d} done "
                    f"{len(done):3d} backlog {bl:12s} migrated {mig}")
            if dvfs_on:
                # per-slice solved clock, one column per engine
                clk = "/".join(
                    f"{w.reports[-1].clock:.2f}"
                    if w.reports and w.reports[-1].clock is not None
                    else "-" for w in workers)
                line += f" clk {clk}"
            print(line)

        res = fleet.run(trace, verbose_cb=cb)
        s = summarize(res)
        if dvfs_on:
            clocks = sorted(r.clock for w in fleet.workers
                            for r in w.reports if r.clock is not None)
            mean = sum(clocks) / len(clocks) if clocks else float("nan")
            print(f"dvfs-controller: {len(grid)}-point grid, solved clock "
                  f"min {clocks[0]:.2f} / mean {mean:.2f} / max "
                  f"{clocks[-1]:.2f} over {len(clocks)} engine-slices")
    print(f"completed {s.n_completed}/{s.n_submitted} "
          f"(rejected {s.n_rejected}) over {s.n_slices} slices")
    print(f"latency   p50 {s.p50_ms * 1e3:.2f} us | "
          f"p95 {s.p95_ms * 1e3:.2f} us | p99 {s.p99_ms * 1e3:.2f} us "
          f"(SLO {s.slo_ms * 1e3:.2f} us)")
    print(f"deadline-miss-rate {s.deadline_miss_rate:.3f}")
    print(f"energy    {s.energy_uj:.1f} uJ total, "
          f"{s.energy_per_token_uj:.2f} uJ/token over {s.tokens} tokens")
    print(f"placement {s.migrations} migrating slices, "
          f"{s.weights_moved} weights moved")
    if hier is not None and args.autoscale:
        print(f"autoscale {res.n_scale_ups} up / {res.n_scale_downs} down, "
              f"engines {res.n_engines_start} -> peak "
              f"{res.n_engines_peak} -> end {res.n_engines_end}, "
              f"scale-up LUT builds {res.scale_up_builds}")
    # the compiler's cache traffic, printed unconditionally: warm-started
    # runs (and autoscaler scale-ups) must show "0 builds" here
    print(f"lut-cache: {len(pc)} LUTs ({pc.n_builds} builds, "
          f"{pc.n_hits} hits, {pc.n_loaded} loaded)")
    if args.lut_cache:
        pc.save(args.lut_cache)
        print(f"lut-cache: saved {len(pc)} LUTs to {args.lut_cache}")
    if obs_on:
        rec = obs.flight_recorder()
        if rec is not None:
            if rec.n_dumps:
                print(f"flight-recorder: {rec.n_dumps} SLO-breach dump(s) "
                      f"-> {args.flight_recorder} "
                      f"({rec.last_dump['reason']})")
            else:
                print(f"flight-recorder: no SLO breach "
                      f"({len(rec)} frames buffered)")
        if args.trace is not None:
            paths = obs.export(
                trace_path=args.trace,
                metrics_path=Path(args.trace).with_name("metrics.json"))
            print(f"wrote {paths['trace']} ({len(obs.tracer())} events; "
                  f"load at ui.perfetto.dev) and {paths['metrics']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(s.as_dict(), f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
