"""Fleet serving launcher: ``python -m repro.launch.fleet``.

Runs a trace-driven multi-engine serving fleet: N HH-PIM serve engines
(TPU parameterization), per-engine load forecasting driving proactive
weight migration, SLO-aware routing with optional admission control.

    python -m repro.launch.fleet --workload mmpp --engines 2 --requests 32
    python -m repro.launch.fleet --substrate gpu-pool --dvfs-controller ...
    python -m repro.launch.fleet --substrate cxl-tier-3 \\
        --lut-cache ckpt/luts.json ...                    # warm-start
    python -m repro.launch.fleet --trace --flight-recorder ...  # DESIGN SS.8
    python -m repro.launch.fleet --cells 16 --engines 128 \\
        --autoscale --max-engines 512 --no-decode          # DESIGN SS.9
    python -m repro.launch.fleet --workload dag:mixed --cells 4 \\
        --engines 8                                        # DESIGN SS.11

``--workload dag:<spec>`` switches to the multi-tenant DAG-serving
fleet (:mod:`repro.fleet.dag`): requests become stage DAGs
(``dag:mixed`` runs the stock mixed-tenant registry; ``dag:agentic`` /
``dag:prefill_decode`` / ``dag:draft_verify`` run one canonical spec
for an interactive + a batch tenant), stages are co-scheduled across
cells against the bring-up placement LUTs, and the summary gains
per-tenant columns. ``--tenants name:class[:spec[:weight]],...``
replaces the registry; unknown spec names raise shaped errors listing
the registered ones. ``--request-level`` pins every stage to its DAG's
admission cell (the baseline ``fleet_bench --suite dag_serving``
compares against).

``--cells N`` switches to the two-level hierarchical fleet
(:mod:`repro.fleet.hierarchy`): ``--engines`` becomes the total initial
engine count split evenly across N cells, the global tier routes by
queue-aware per-class scoring, and ``--autoscale`` attaches the cell
autoscaler (``--max-engines`` caps the total; scale-ups are served from
placement-compiler warm starts, so the ``lut-cache:`` line must report
0 builds on a warm run). The hierarchical path is analytic-only.

``--trace [PATH]`` turns on the observability layer (repro.obs) and
writes a Perfetto-loadable ``trace.json`` plus a ``metrics.json``
snapshot after the run; ``--flight-recorder [PATH]`` arms the SLO-breach
flight recorder (ring buffer of per-slice fleet state, dumped as JSON
when the running deadline-miss rate crosses ``--miss-threshold``).

With ``--decode`` (default on the flat path) every worker carries a real
``HeteroServeEngine``: each slice's placement is applied as an actual
weight re-tiering and tokens are decoded through the tiered model on CPU.
``--no-decode`` runs the analytic scheduler/energy path only (fast; what
``benchmarks/fleet_bench.py`` sweeps).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro import api, obs
from repro.fleet import make_trace, summarize
from repro.fleet.forecast import FORECASTERS
from repro.fleet.hierarchy import CELL_POLICIES
from repro.fleet.router import POLICIES
from repro.fleet.traces import TRACES


def _dag_tenants(spec_str):
    """Parse ``--tenants name:slo_class[:dag_spec[:weight]],...`` into a
    TenantRegistry (shaped errors surface as SystemExit)."""
    from repro.fleet.dag import Tenant, TenantRegistry
    tenants = []
    for part in spec_str.split(","):
        bits = part.split(":")
        if len(bits) < 2 or not bits[0] or not bits[1]:
            raise SystemExit(
                f"bad --tenants entry {part!r}; expected "
                f"name:slo_class[:dag_spec[:weight]]")
        dag = bits[2] if len(bits) > 2 and bits[2] else "prefill_decode"
        weight = float(bits[3]) if len(bits) > 3 else 1.0
        try:
            tenants.append(Tenant(bits[0], bits[1], weight=weight,
                                  dag=dag))
        except ValueError as e:
            raise SystemExit(f"--tenants: {e}") from None
    return TenantRegistry(tuple(tenants))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workload", default="mmpp",
                    help=f"arrival trace: one of {sorted(TRACES)}, a "
                         f"case* scenario, or dag:<spec> for the DAG "
                         f"fleet (default mmpp)")
    ap.add_argument("--tenants", default=None, metavar="SPEC",
                    help="DAG tenant registry: comma-separated "
                         "name:slo_class[:dag_spec[:weight]] entries "
                         "(dag:* workloads; default: the stock mixed "
                         "registry)")
    ap.add_argument("--dag-base", default="mmpp", metavar="TRACE",
                    help="arrival process under a dag:* workload "
                         "(default mmpp)")
    ap.add_argument("--request-level", action="store_true",
                    help="disable stage affinity: route whole DAGs at "
                         "admission (comparison baseline)")
    ap.add_argument("--trace", nargs="?", const="trace.json", default=None,
                    metavar="PATH",
                    help="enable structured tracing; write Chrome "
                         "trace-event JSON to PATH (default trace.json, "
                         "with a metrics.json snapshot alongside)")
    ap.add_argument("--flight-recorder", nargs="?", const="flight.json",
                    default=None, metavar="PATH",
                    help="arm the SLO-breach flight recorder; dump the "
                         "last --flight-capacity slice frames to PATH "
                         "when the running deadline-miss rate crosses "
                         "--miss-threshold")
    ap.add_argument("--flight-capacity", type=int, default=32)
    ap.add_argument("--miss-threshold", type=float, default=0.3,
                    help="flight-recorder deadline-miss-rate trigger")
    ap.add_argument("--engines", type=int, default=2,
                    help="engine count (with --cells: total across cells)")
    ap.add_argument("--cells", type=int, default=None, metavar="N",
                    help="hierarchical fleet with N cells (two-level "
                         "router + per-class SLO admission; DESIGN SS.9)")
    ap.add_argument("--autoscale", action="store_true",
                    help="attach the cell autoscaler (requires --cells)")
    ap.add_argument("--max-engines", type=int, default=None,
                    help="autoscale ceiling, total across cells "
                         "(default: --engines, i.e. no growth)")
    ap.add_argument("--cell-policy", default="least_loaded",
                    choices=CELL_POLICIES,
                    help="engine selection inside a cell")
    ap.add_argument("--requests", type=int, default=None,
                    help="total request budget (truncates the trace)")
    ap.add_argument("--steps", type=int, default=25,
                    help="number of trace time slices")
    ap.add_argument("--forecaster", default="ewma",
                    choices=sorted(FORECASTERS))
    ap.add_argument("--policy", default="slo", choices=POLICIES)
    ap.add_argument("--margin", type=float, default=1.0,
                    help="forecast over-provisioning factor")
    ap.add_argument("--admission-limit", type=int, default=None,
                    help="max queued tasks per engine before rejecting "
                         "(flat fleet; --cells admits by expected wait)")
    ap.add_argument("--substrate", default=None,
                    help=f"one of {api.available_substrates()} "
                         f"(default tpu-pool; --mixed => tpu-pool-mixed)")
    ap.add_argument("--solver", default=None,
                    help=f"placement solver, one of {sorted(api.SOLVERS)}")
    ap.add_argument("--mixed", action="store_true",
                    help="heterogeneous pool: odd engines get half chips")
    ap.add_argument("--dvfs-controller", type=int, nargs="?", const=5,
                    default=None, metavar="N",
                    help="solve the DVFS clock online: pick the energy-"
                         "minimal (placement, clock) pair per slice over "
                         "an N-point TechModel grid (default 5; gpu-pool "
                         "and cxl-tier substrates, flat fleet path). The "
                         "chosen clock prints per slice (clk column) and "
                         "in the dvfs-controller: summary")
    ap.add_argument("--tokens-per-task", type=int, default=2)
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode", dest="decode", action="store_true",
                    default=True)
    ap.add_argument("--no-decode", dest="decode", action="store_false")
    ap.add_argument("--lut-cache", default=None, metavar="PATH",
                    help="warm-start: load the placement-compiler LUT "
                         "cache from PATH when it exists and save it back "
                         "after the run (serialize next to checkpoints so "
                         "a restarted fleet skips bring-up compiles)")
    ap.add_argument("--json", default=None,
                    help="write the summary to this path as JSON")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    is_dag = args.workload.startswith("dag:")
    if is_dag and args.cells is None:
        args.cells = 2                # DAG serving is inherently celled
    if not is_dag and args.tenants is not None:
        raise SystemExit("--tenants requires a dag:<spec> workload")

    if args.autoscale and args.cells is None:
        raise SystemExit("--autoscale requires --cells")

    obs_on = args.trace is not None or args.flight_recorder is not None
    if obs_on:
        obs.reset()
        rec = None
        if args.flight_recorder is not None:
            rec = obs.FlightRecorder(
                capacity=args.flight_capacity,
                miss_rate_threshold=args.miss_threshold,
                path=args.flight_recorder)
        obs.enable(flight_recorder=rec)

    trace = None
    if not is_dag:
        trace = make_trace(args.workload, n_slices=args.steps,
                           seed=args.seed)
        if args.requests is not None:
            trace = trace.truncated(args.requests)

    if args.substrate and args.mixed \
            and not args.substrate.endswith("-mixed"):
        raise SystemExit(
            f"--mixed conflicts with --substrate {args.substrate}; "
            f"use a *-mixed substrate such as tpu-pool-mixed or "
            f"gpu-pool-mixed (or drop --mixed)")
    substrate = args.substrate or ("tpu-pool-mixed" if args.mixed
                                   else "tpu-pool")
    over = {"solver": args.solver} if args.solver else {}
    if args.dvfs_controller is not None:
        if args.cells is not None:
            raise SystemExit("--dvfs-controller runs on the flat fleet "
                             "path; drop --cells")
        if api.substrate(substrate, **over).tech_model() is None:
            raise SystemExit(
                f"--dvfs-controller needs a substrate with a registered "
                f"TechModel (gpu-pool / cxl-tier families); "
                f"{substrate} has none")
    if args.decode and args.cells is not None:
        if not args.quiet:
            print("hierarchical fleets run the analytic path only; "
                  "running as --no-decode")
        args.decode = False
    if args.decode and not api.substrate(substrate).supports_decode:
        print(f"substrate {substrate} is accounting-only (no functional "
              f"decode engine); running as --no-decode")
        args.decode = False

    params = cfg = None
    if args.decode:
        import jax
        from repro.configs import canonical, get_smoke_config
        from repro.models import lm
        cfg = get_smoke_config(args.arch)
        params = lm.init_lm(jax.random.PRNGKey(args.seed), cfg)
        print(f"arch={canonical(args.arch)} ({cfg.n_layers}L "
              f"d={cfg.d_model}, reduced config)")

    pc = api.compiler()
    if args.lut_cache:
        n = pc.load(args.lut_cache)
        if n:
            print(f"warm-start: loaded {n} cached LUTs from "
                  f"{args.lut_cache}")

    hier = None
    if is_dag:
        from repro.fleet.dag import (DEFAULT_DAG_BUDGETS, dag_arrivals,
                                     default_tenants, make_dag_spec,
                                     tenant_breakdown)
        spec_name = args.workload[len("dag:"):] or "mixed"
        if args.tenants is not None:
            tenants = _dag_tenants(args.tenants)
        elif spec_name == "mixed":
            tenants = default_tenants()
        else:
            from repro.fleet.dag import Tenant, TenantRegistry
            try:
                make_dag_spec(spec_name)
            except ValueError as e:
                raise SystemExit(f"--workload {args.workload}: {e}") \
                    from None
            tenants = TenantRegistry((
                Tenant("interactive", "interactive", dag=spec_name),
                Tenant("batch", "batch", dag=spec_name),
            ))
        # every tenant class must be registered; the CLI registers
        # unbudgeted ones at the default 2-slice SLO explicitly
        budgets = dict(DEFAULT_DAG_BUDGETS)
        for t in tenants:
            budgets.setdefault(t.slo_class, 2.0)
        per_cell = max(args.engines // args.cells, 1)
        dagf = api.dag_fleet(
            substrate, cfg, tenants=tenants, budgets=budgets,
            stage_affinity=not args.request_level,
            n_cells=args.cells, engines_per_cell=per_cell,
            forecaster=args.forecaster, cell_policy=args.cell_policy,
            autoscale=args.autoscale,
            tokens_per_task=args.tokens_per_task,
            forecast_margin=args.margin, compiler=pc, seed=args.seed,
            **over)
        dag_tr = dag_arrivals(tenants, n_slices=args.steps,
                              base=args.dag_base, seed=args.seed)
        T_us = dagf.cells[0].t_slice_ns / 1e3
        mode = "request-level" if args.request_level else "stage-level"
        print(f"dag fleet: {args.cells} cells x {per_cell} engines on "
              f"{substrate}, {mode} placement, "
              f"tenants={','.join(tenants.names())}, "
              f"t_slice={T_us:.2f} us, trace={dag_tr.name} "
              f"({dag_tr.total} dags / {len(dag_tr)} slices)")

        def cb(s, arrivals, done_dags, cells):
            if args.quiet:
                return
            bl = "/".join(str(c.backlog) for c in cells)
            print(f"  slice {s:3d} dags-in {len(arrivals):3d} dags-done "
                  f"{done_dags:3d} backlog {bl}")

        res = dagf.run_dag(dag_tr, verbose_cb=cb)
        s = summarize(res)
        n_dags = (len(res.completed) + len(res.rejected)
                  + len(res.unfinished))
        print(f"dags: completed {len(res.completed)}/{n_dags} "
              f"(rejected {len(res.rejected)}, unfinished "
              f"{len(res.unfinished)}), {res.handoffs} handoffs "
              f"({res.handoff_energy_pj / 1e6:.2f} uJ handoff energy)")
        tb = tenant_breakdown(res, dagf)
        print(f"{'tenant':<10s} {'class':<12s} {'dag':<15s} "
              f"{'done':>5s} {'rej':>4s} {'unf':>4s} {'miss':>6s} "
              f"{'p95_us':>8s} {'handoffs':>8s}")
        for name, row in tb.items():
            print(f"{name:<10s} {row['slo_class']:<12s} "
                  f"{row['dag']:<15s} {row['n_completed']:5d} "
                  f"{row['n_rejected']:4d} {row['n_unfinished']:4d} "
                  f"{row['deadline_miss_rate']:6.3f} "
                  f"{row['p95_ms'] * 1e3:8.2f} {row['handoffs']:8d}")
    elif args.cells is not None:
        per_cell = max(args.engines // args.cells, 1)
        max_per_cell = (per_cell if args.max_engines is None
                        else max(args.max_engines // args.cells, per_cell))
        hier = api.hierarchical_fleet(
            substrate, cfg, n_cells=args.cells,
            engines_per_cell=per_cell, forecaster=args.forecaster,
            cell_policy=args.cell_policy,
            autoscale=args.autoscale, max_engines=max_per_cell,
            tokens_per_task=args.tokens_per_task,
            forecast_margin=args.margin, compiler=pc, seed=args.seed,
            **over)
        n_engines = hier.n_engines
        T_us = hier.cells[0].t_slice_ns / 1e3
        ceiling = (f" (ceiling {max_per_cell * args.cells})"
                   if args.autoscale else "")
        print(f"fleet: {args.cells} cells x {per_cell} engines "
              f"({n_engines} total) on {substrate}, "
              f"cell-policy={args.cell_policy}, "
              f"autoscale={'on' if args.autoscale else 'off'}{ceiling}, "
              f"forecaster={args.forecaster}, t_slice={T_us:.2f} us, "
              f"trace={trace.name} ({trace.total} requests / "
              f"{len(trace)} slices, peak {trace.peak}/slice)")

        def cb(s, n_arr, done, cells):
            if args.quiet:
                return
            bl = "/".join(str(c.backlog) for c in cells)
            eng = "/".join(str(c.n_active) for c in cells)
            print(f"  slice {s:3d} arrivals {n_arr:4d} done "
                  f"{len(done):4d} backlog {bl} engines {eng}")

        res = hier.run(trace, verbose_cb=cb)
        s = summarize(res)
    else:
        fleet = api.fleet(
            substrate, cfg, n_engines=args.engines,
            forecaster=args.forecaster, policy=args.policy,
            tokens_per_task=args.tokens_per_task,
            admission_limit=args.admission_limit,
            forecast_margin=args.margin, params=params,
            decode=args.decode, compiler=pc,
            dvfs=args.dvfs_controller, **over)

        T_us = fleet.workers[0].t_slice_ns / 1e3
        dvfs_on = args.dvfs_controller is not None
        grid = fleet.workers[0].sched.dvfs.clocks if dvfs_on else ()
        print(f"fleet: {args.engines} engines on {substrate}"
              f", policy={args.policy}, forecaster={args.forecaster}, "
              f"t_slice={T_us:.2f} us, trace={trace.name} "
              f"({trace.total} requests / {len(trace)} slices, "
              f"peak {trace.peak}/slice)"
              + (f", dvfs-grid=[{'/'.join(f'{c:.2f}' for c in grid)}]"
                 if dvfs_on else ""))

        def cb(s, n_arr, done, workers):
            if args.quiet:
                return
            bl = "/".join(str(len(w.backlog)) for w in workers)
            mig = "/".join(
                "y" if (w.reports and w.reports[-1].moved_weights) else "."
                for w in workers)
            line = (f"  slice {s:3d} arrivals {n_arr:3d} done "
                    f"{len(done):3d} backlog {bl:12s} migrated {mig}")
            if dvfs_on:
                # per-slice solved clock, one column per engine
                clk = "/".join(
                    f"{w.reports[-1].clock:.2f}"
                    if w.reports and w.reports[-1].clock is not None
                    else "-" for w in workers)
                line += f" clk {clk}"
            print(line)

        res = fleet.run(trace, verbose_cb=cb)
        s = summarize(res)
        if dvfs_on:
            clocks = sorted(r.clock for w in fleet.workers
                            for r in w.reports if r.clock is not None)
            mean = sum(clocks) / len(clocks) if clocks else float("nan")
            print(f"dvfs-controller: {len(grid)}-point grid, solved clock "
                  f"min {clocks[0]:.2f} / mean {mean:.2f} / max "
                  f"{clocks[-1]:.2f} over {len(clocks)} engine-slices")
    print(f"completed {s.n_completed}/{s.n_submitted} "
          f"(rejected {s.n_rejected}) over {s.n_slices} slices")
    print(f"latency   p50 {s.p50_ms * 1e3:.2f} us | "
          f"p95 {s.p95_ms * 1e3:.2f} us | p99 {s.p99_ms * 1e3:.2f} us "
          f"(SLO {s.slo_ms * 1e3:.2f} us)")
    print(f"deadline-miss-rate {s.deadline_miss_rate:.3f}")
    print(f"energy    {s.energy_uj:.1f} uJ total, "
          f"{s.energy_per_token_uj:.2f} uJ/token over {s.tokens} tokens")
    print(f"placement {s.migrations} migrating slices, "
          f"{s.weights_moved} weights moved")
    if hier is not None and args.autoscale:
        print(f"autoscale {res.n_scale_ups} up / {res.n_scale_downs} down, "
              f"engines {res.n_engines_start} -> peak "
              f"{res.n_engines_peak} -> end {res.n_engines_end}, "
              f"scale-up LUT builds {res.scale_up_builds}")
    # the compiler's cache traffic, printed unconditionally: warm-started
    # runs (and autoscaler scale-ups) must show "0 builds" here
    print(f"lut-cache: {len(pc)} LUTs ({pc.n_builds} builds, "
          f"{pc.n_hits} hits, {pc.n_loaded} loaded)")
    if args.lut_cache:
        pc.save(args.lut_cache)
        print(f"lut-cache: saved {len(pc)} LUTs to {args.lut_cache}")
    if obs_on:
        rec = obs.flight_recorder()
        if rec is not None:
            if rec.n_dumps:
                print(f"flight-recorder: {rec.n_dumps} SLO-breach dump(s) "
                      f"-> {args.flight_recorder} "
                      f"({rec.last_dump['reason']})")
            else:
                print(f"flight-recorder: no SLO breach "
                      f"({len(rec)} frames buffered)")
        if args.trace is not None:
            paths = obs.export(
                trace_path=args.trace,
                metrics_path=Path(args.trace).with_name("metrics.json"))
            print(f"wrote {paths['trace']} ({len(obs.tracer())} events; "
                  f"load at ui.perfetto.dev) and {paths['metrics']}")
    if args.json:
        out = s.as_dict()
        if is_dag:
            out["dag"] = {
                "n_completed": len(res.completed),
                "n_rejected": len(res.rejected),
                "n_unfinished": len(res.unfinished),
                "handoffs": res.handoffs,
                "handoff_energy_pj": res.handoff_energy_pj,
                "tenants": tb,
            }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
