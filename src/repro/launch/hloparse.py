"""Post-optimization HLO parsing: collective bytes with while-loop trip
accounting.

XLA's ``cost_analysis`` counts a while body ONCE regardless of trip count
(verified experimentally - see EXPERIMENTS.md SS.Roofline/Method), and the
same holds for naive text scans. Here we parse the compiled module into
computations, find ``while`` ops, extract their trip counts from the loop
condition's comparison constant, and propagate multipliers through the call
graph, so a collective inside the layer scan counts n_layers times.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLLECTIVE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[\w\[\]{},]+?))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_WHILE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)"
    r"(?:.*?known_trip_count.{0,8}?n.{0,4}?(\d+))?")
_CONST = re.compile(r"constant\((\d+)\)")
_CALLEE = re.compile(
    r"(?:to_apply|body|condition|branch_computations=\{[^}]*|calls)"
    r"=\{?%?([\w.\-]+(?:\s*,\s*%?[\w.\-]+)*)\}?")


def split_computations(hlo: str) -> Dict[str, str]:
    comps: Dict[str, list] = {}
    name = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and line.rstrip().endswith("{"):
            name = m.group(1)
            comps[name] = []
        elif name is not None:
            if line.strip() == "}":
                name = None
            else:
                comps[name].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(sig):
        base = _DTYPE_BYTES.get(dt)
        if base is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * base
    return total


def collective_bytes_in(body: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for sig, kind in _COLLECTIVE.findall(body):
        out[kind] = out.get(kind, 0) + shape_bytes(sig)
    return out


def while_trip_count(cond_body: str) -> int:
    consts = [int(c) for c in _CONST.findall(cond_body)]
    return max(consts) if consts else 1


def computation_multipliers(comps: Dict[str, str], entry: str
                            ) -> Dict[str, float]:
    """Walk the call graph from entry; while bodies multiply by trip count,
    everything else (calls, fusions, conditional branches) by 1."""
    mult: Dict[str, float] = {}

    def visit(name: str, m: float) -> None:
        mult[name] = mult.get(name, 0.0) + m
        body = comps.get(name, "")
        for wm in _WHILE.finditer(body):
            cond, wbody, n = wm.group(1), wm.group(2), wm.group(3)
            trips = int(n) if n else while_trip_count(comps.get(cond, ""))
            visit(cond, m * trips)
            visit(wbody, m * trips)
        seen_here = set()
        for cm in re.finditer(
                r"(?:to_apply=|calls=)%?([\w.\-]+)", body):
            callee = cm.group(1)
            if callee in comps and callee not in seen_here:
                seen_here.add(callee)
                # count each to_apply target once per textual occurrence
                visit(callee, m)

    visit(entry, 1.0)
    return mult


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Loop-corrected collective bytes per device, by op kind."""
    comps = split_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:
        entry = next(iter(comps), None)
    if entry is None:
        return {"total": 0.0}
    mult = computation_multipliers(comps, entry)
    out: Dict[str, float] = {}
    uncounted = 0
    for name, body in comps.items():
        m = mult.get(name)
        if m is None:
            # computation not reached through the walked edges (e.g. fusion
            # internals) - count once
            m = 1.0
            if _COLLECTIVE.search(body):
                uncounted += 1
        for kind, b in collective_bytes_in(body).items():
            out[kind] = out.get(kind, 0.0) + b * m
    out["total"] = sum(v for k, v in out.items() if k != "total")
    if uncounted:
        out["computations_counted_once"] = uncounted
    return out


def while_summary(hlo: str) -> Dict[str, int]:
    comps = split_computations(hlo)
    out = {}
    for name, body in comps.items():
        for wm in _WHILE.finditer(body):
            cond, wbody, n = wm.group(1), wm.group(2), wm.group(3)
            out[wbody] = (int(n) if n
                          else while_trip_count(comps.get(cond, "")))
    return out
