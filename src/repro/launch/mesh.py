"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (assignment requirement).

Single pod : (data=16, model=16)            - 256 chips (TPU v5e pod).
Multi-pod  : (pod=2, data=16, model=16)     - 512 chips across 2 pods; the
"pod" axis carries pure data parallelism (params replicated per pod, grads
all-reduced across the DCI), matching how real multi-pod training slices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small host-device mesh for CPU integration tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set by the caller's
    process, NOT globally)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
