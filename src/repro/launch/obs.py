"""Observability launcher: ``python -m repro.launch.obs``.

Runs one instrumented fleet scenario end-to-end with tracing, metrics
and the SLO-breach flight recorder enabled, then renders a text summary
(top spans by total wall time, counters, histograms, flight-recorder
status) and writes ``trace.json`` (Chrome trace-event JSON - load it at
ui.perfetto.dev) plus ``metrics.json`` (registry snapshot):

    python -m repro.launch.obs --workload mmpp --engines 2 --steps 25
    python -m repro.launch.obs --summarize out/trace.json   # re-render

The heavier fleet CLI (``repro.launch.fleet``) exposes the same layer
via ``--trace``/``--flight-recorder`` on its full option surface; this
launcher is the quick one-command way to get an attributable timeline.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro import api, obs
from repro.fleet import make_trace, summarize
from repro.fleet.traces import TRACES


def render_spans(events, limit: int = 20) -> None:
    rows = obs.summarize_events(events)
    print(f"spans ({sum(r['count'] for r in rows)} events, "
          f"{len(rows)} names; top {min(limit, len(rows))} by total time)")
    print(f"  {'name':26s} {'cat':10s} {'count':>6s} {'total_us':>10s} "
          f"{'mean_us':>9s} {'max_us':>9s}")
    for r in rows[:limit]:
        print(f"  {r['name']:26s} {r['cat']:10s} {r['count']:6d} "
              f"{r['total_us']:10.1f} {r['mean_us']:9.1f} "
              f"{r['max_us']:9.1f}")


def render_metrics(reg: obs.MetricsRegistry) -> None:
    lines = reg.render()
    print(f"metrics ({len(lines)} instruments)")
    for line in lines:
        print(f"  {line}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--summarize", default=None, metavar="TRACE_JSON",
                    help="render the span summary of an existing trace "
                         "file and exit (no fleet run)")
    ap.add_argument("--workload", default="mmpp",
                    help=f"arrival trace: one of {sorted(TRACES)} or a "
                         f"case* scenario")
    ap.add_argument("--substrate", default="tpu-pool",
                    help=f"one of {api.available_substrates()}")
    ap.add_argument("--engines", type=int, default=2)
    ap.add_argument("--steps", type=int, default=25)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--forecaster", default="ewma")
    ap.add_argument("--admission-limit", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default="obs_out", metavar="DIR",
                    help="where trace.json / metrics.json / flight.json "
                         "are written")
    ap.add_argument("--flight-capacity", type=int, default=32)
    ap.add_argument("--miss-threshold", type=float, default=0.3,
                    help="flight-recorder deadline-miss-rate trigger")
    args = ap.parse_args(argv)

    if args.summarize:
        payload = json.loads(Path(args.summarize).read_text())
        events = payload.get("traceEvents", payload)
        render_spans(events)
        return

    out = Path(args.out_dir)
    obs.reset()
    obs.enable(flight_recorder=obs.FlightRecorder(
        capacity=args.flight_capacity,
        miss_rate_threshold=args.miss_threshold,
        path=out / "flight.json"))

    trace = make_trace(args.workload, n_slices=args.steps, seed=args.seed)
    if args.requests is not None:
        trace = trace.truncated(args.requests)
    fleet = api.fleet(args.substrate, n_engines=args.engines,
                      forecaster=args.forecaster,
                      admission_limit=args.admission_limit)
    res = fleet.run(trace)
    s = summarize(res)

    print(f"fleet: {args.engines} engines on {args.substrate}, "
          f"workload={trace.name} ({trace.total} requests / "
          f"{len(trace)} slices)")
    print(f"completed {s.n_completed}/{s.n_submitted}, miss-rate "
          f"{s.deadline_miss_rate:.3f}, p99 {s.p99_ms * 1e3:.2f} us "
          f"(SLO {s.slo_ms * 1e3:.2f} us)")
    print()
    render_spans(obs.tracer().events())
    print()
    render_metrics(obs.metrics())

    rec = obs.flight_recorder()
    if rec.n_dumps:
        print(f"\nflight-recorder: {rec.n_dumps} SLO-breach dump(s), "
              f"last reason: {rec.last_dump['reason']}")
    else:
        print(f"\nflight-recorder: armed, no SLO breach "
              f"({len(rec)} frames buffered)")

    paths = obs.export(trace_path=out / "trace.json",
                       metrics_path=out / "metrics.json")
    for kind, p in paths.items():
        print(f"wrote {kind}: {p}")
    print("open the trace at https://ui.perfetto.dev (or "
          "chrome://tracing)")


if __name__ == "__main__":
    main()
