"""Roofline analysis per (arch x shape x mesh) cell.

Terms per the assignment, with one methodological correction documented in
EXPERIMENTS.md: XLA's ``cost_analysis()`` counts a ``while`` body ONCE
regardless of trip count (verified: a 10-iteration scan of a matmul reports
1 matmul of FLOPs), so for scan-structured models its FLOPs/bytes are
10-100x under-counted. We therefore use an ANALYTIC per-op counter
(mirroring exactly what the lowered HLO executes: chunked-attention full-
rectangle scores, MoE capacity slack, remat recompute, CE-chunk recompute)
as the primary HLO_FLOPs/bytes, validated against ``cost_analysis`` on
unrolled reduced configs (tests/test_roofline.py), while collective bytes
come from the compiled HLO with while-trip multipliers
(repro.launch.hloparse).

Hardware constants (TPU v5e class): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path
from typing import Dict, Optional

from repro.configs import ARCH_IDS, get_config
from repro.launch.specs import SHAPES, cell_is_applicable, dryrun_config
from repro.models.common import ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = {"single": 256, "multi": 512}
ATTN_CHUNK = 512


def param_count(cfg: ModelConfig) -> Dict[str, float]:
    """Per-component parameter counts (matches lm.init_lm structure)."""
    d, hd = cfg.d_model, cfg.hd
    attn = d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2
    glu = cfg.mlp_act in ("swiglu", "geglu")
    ffn_dense = (3 if glu else 2) * d * cfg.d_ff
    rglru = 5 * d * d + 4 * d               # in_x,in_g,a,x,out + conv
    mlstm = d * cfg.n_heads * hd * 5 + 2 * d * cfg.n_heads
    slstm = 5 * d * d
    per_kind = {"attn": attn, "rglru": rglru, "mlstm": mlstm,
                "slstm": slstm}
    pattern = cfg.pattern_for_depth()
    mix = sum(per_kind[k] for k in pattern)
    ffn = 0.0
    moe = 0.0
    for k in pattern:
        if k in ("mlstm", "slstm") and not cfg.d_ff:
            continue
        if cfg.n_experts and k == "attn":
            moe += cfg.n_experts * 3 * d * cfg.d_ff + d * cfg.n_experts
            if cfg.moe_dense_ff:
                ffn += (3 if glu else 2) * d * cfg.moe_dense_ff
        else:
            ffn += ffn_dense
    enc = 0.0
    if cfg.is_encdec:
        enc = cfg.n_encoder_layers * (attn + ffn_dense)
        mix += len(pattern) * attn          # decoder cross attention
    embed = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return {"mix": mix, "ffn": ffn, "moe": moe, "enc": enc, "embed": embed,
            "total": mix + ffn + moe + enc + embed}


def active_params(cfg: ModelConfig) -> float:
    """Per-token active params (MoE: top-k experts only)."""
    pc = param_count(cfg)
    active_moe = 0.0
    if cfg.n_experts:
        active_moe = pc["moe"] * cfg.experts_per_token / cfg.n_experts
    return pc["mix"] + pc["ffn"] + active_moe + pc["enc"] + pc["embed"]


@dataclasses.dataclass
class CellCost:
    flops: float          # global per step, as executed by the HLO
    hbm_bytes: float      # global per step
    model_flops: float    # 6*N_active*D reference (train) / 2*N*D (serve)


def _attn_flops_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    """Scores+PV fwd FLOPs, as executed: chunked path computes the FULL
    S x S rectangle (masked blocks included); local path S x (W + chunk)."""
    width = cfg.n_heads * cfg.hd
    f = 0.0
    for k in cfg.pattern_for_depth():
        if k != "attn":
            continue
        if cfg.attn_kind == "local" and cfg.local_window < S:
            kspan = cfg.local_window + ATTN_CHUNK
        else:
            kspan = S
        f += 4.0 * B * S * kspan * width
    return f


def _recurrent_flops_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    f = 0.0
    for k in cfg.pattern_for_depth():
        if k == "mlstm":
            f += 5.0 * B * S * cfg.n_heads * cfg.hd * cfg.hd
        elif k in ("rglru", "slstm"):
            f += 12.0 * B * S * cfg.d_model      # elementwise recurrences
    return f


def _matmul_flops_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    """All projection/FFN/MoE/logits matmuls, fwd, as executed."""
    pc = param_count(cfg)
    moe_exec = 0.0
    if cfg.n_experts:
        # capacity-slotted GEMMs: E*C rows with C = tb*k/E * cf
        moe_exec = (pc["moe"] - cfg.d_model * cfg.n_experts) \
            * cfg.experts_per_token / cfg.n_experts * cfg.moe_capacity_factor
        moe_exec += cfg.d_model * cfg.n_experts          # router
    dense = pc["mix"] + pc["ffn"] + pc["enc"]
    head = cfg.vocab_size * cfg.d_model                  # lm head matmul
    return 2.0 * B * S * (dense + moe_exec + head)


def _enc_attn_extra(cfg: ModelConfig, B: int, S: int) -> float:
    if not cfg.is_encdec:
        return 0.0
    Se = max(S // cfg.enc_len_divisor, 1)
    width = cfg.n_heads * cfg.hd
    enc_self = 4.0 * B * Se * Se * width * cfg.n_encoder_layers
    cross = 4.0 * B * S * Se * width * cfg.n_layers
    return enc_self + cross


def train_cost(cfg: ModelConfig, S: int, B: int, n_micro: int) -> CellCost:
    fwd = (_matmul_flops_fwd(cfg, B, S) + _attn_flops_fwd(cfg, B, S)
           + _recurrent_flops_fwd(cfg, B, S) + _enc_attn_extra(cfg, B, S))
    # fwd + bwd(2x) + remat recompute of fwd (checkpointed blocks + CE)
    flops = fwd * 4.0
    N = param_count(cfg)["total"]
    pbytes = N * 2.0
    D = B * S
    hbm = (3 * pbytes                       # weights: fwd + remat + bwd
           + 2 * n_micro * pbytes           # grad accumulation r/w
           + 6 * pbytes                     # optimizer read/write + states
           + 10.0 * B * S * cfg.d_model * 2 * cfg.n_layers)  # act streams
    return CellCost(flops, hbm, 6.0 * active_params(cfg) * D)


def prefill_cost(cfg: ModelConfig, S: int, B: int) -> CellCost:
    fwd = (_matmul_flops_fwd(cfg, B, S) + _attn_flops_fwd(cfg, B, S)
           + _recurrent_flops_fwd(cfg, B, S) + _enc_attn_extra(cfg, B, S))
    # last-position-only head: subtract the full-seq head matmul, add 1 pos
    fwd -= 2.0 * B * (S - 1) * cfg.vocab_size * cfg.d_model
    N = param_count(cfg)["total"]
    hbm = N * 2.0 + 8.0 * B * S * cfg.d_model * 2 * cfg.n_layers
    return CellCost(fwd, hbm, 2.0 * active_params(cfg) * B * S)


def decode_cost(cfg: ModelConfig, S: int, B: int) -> CellCost:
    """One token per sequence with a KV/recurrent state of length S."""
    fwd = (_matmul_flops_fwd(cfg, B, 1) + _recurrent_flops_fwd(cfg, B, 1))
    kv_bytes = 0.0
    width_kv = cfg.n_kv_heads * cfg.hd
    for k in cfg.pattern_for_depth():
        if k == "attn":
            span = min(S, cfg.local_window) if cfg.attn_kind == "local" \
                else S
            fwd += 4.0 * B * span * cfg.n_heads * cfg.hd
            kv_bytes += 2.0 * B * span * width_kv * 2  # read k+v, bf16
        elif k == "mlstm":
            fwd += 5.0 * B * cfg.n_heads * cfg.hd * cfg.hd
            kv_bytes += 2.0 * B * cfg.n_heads * cfg.hd * cfg.hd * 4
        elif k in ("rglru", "slstm"):
            kv_bytes += 4.0 * B * cfg.d_model * 4
    if cfg.is_encdec:
        Se = max(S // cfg.enc_len_divisor, 1)
        fwd += 4.0 * B * Se * cfg.n_heads * cfg.hd * cfg.n_layers
        kv_bytes += 2.0 * B * Se * cfg.d_model * 2
    N = param_count(cfg)["total"]
    hbm = N * 2.0 + kv_bytes
    return CellCost(fwd, hbm, 2.0 * active_params(cfg) * B)


def cell_cost(cfg: ModelConfig, shape: str, n_micro: int = 8) -> CellCost:
    S, B, kind = SHAPES[shape]
    if kind == "train":
        return train_cost(cfg, S, B, n_micro)
    if kind == "prefill":
        return prefill_cost(cfg, S, B)
    return decode_cost(cfg, S, B)


def roofline_row(arch: str, shape: str, mesh_kind: str,
                 dryrun_dir: Path) -> Optional[Dict]:
    cfg = get_config(arch)
    ok, why = cell_is_applicable(cfg, shape)
    rec_file = dryrun_dir / f"{arch}__{shape}__{mesh_kind}.json"
    rec = json.loads(rec_file.read_text()) if rec_file.exists() else {}
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "skipped", "why": why}
    chips = CHIPS[mesh_kind]
    cost = cell_cost(dryrun_config(cfg), shape,
                     n_micro=rec.get("microbatches", 8))
    compute_s = cost.flops / (chips * PEAK_FLOPS)
    memory_s = cost.hbm_bytes / (chips * HBM_BW)
    coll_bytes = rec.get("collectives", {}).get("total", 0.0)
    collective_s = coll_bytes / ICI_BW          # per-device bytes / link BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = compute_s / bound if bound > 0 else 0.0
    return {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "status": "ok",
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "roofline_fraction": frac,
        "model_flops": cost.model_flops, "hlo_flops": cost.flops,
        "useful_ratio": cost.model_flops / cost.flops,
        "mem_gib_per_dev": round(
            (rec.get("memory", {}).get("argument_size_in_bytes", 0)
             + rec.get("memory", {}).get("temp_size_in_bytes", 0)) / 2**30,
            2),
        "coll_bytes_per_dev": coll_bytes,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="experiments/roofline.json")
    args = ap.parse_args()
    rows = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            row = roofline_row(arch, shape, args.mesh,
                               Path(args.dryrun_dir))
            rows.append(row)
            if row["status"] == "ok":
                print(f"{arch:24s} {shape:12s} "
                      f"C={row['compute_s']*1e3:9.3f}ms "
                      f"M={row['memory_s']*1e3:9.3f}ms "
                      f"X={row['collective_s']*1e3:9.3f}ms "
                      f"dom={row['dominant']:10s} "
                      f"frac={row['roofline_fraction']:.3f} "
                      f"useful={row['useful_ratio']:.2f}")
            else:
                print(f"{arch:24s} {shape:12s} skipped")
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
