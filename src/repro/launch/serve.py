"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Two modes:
  * ``--engine batch``  - plain batched decode engine (slot continuous
    batching) on the reduced config.
  * ``--engine hetero`` - the HH-PIM heterogeneous runtime: requests flow
    through time slices, weight placement re-solved per slice across
    {hp,lp} x {bf16,int8} tiers (the paper's technique, TPU constants).
    Built through the ``repro.api`` facade; ``--substrate`` / ``--solver``
    pick registry entries (DESIGN.md SS.5).
"""
from __future__ import annotations

import argparse

import jax

from repro import api
from repro.configs import ARCH_IDS, canonical, get_smoke_config
from repro.core import workloads
from repro.models import lm
from repro.serve.engine import DecodeEngine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b",
                    help=f"one of {ARCH_IDS}")
    ap.add_argument("--engine", choices=("batch", "hetero"),
                    default="hetero")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--scenario", default="case6_random")
    ap.add_argument("--substrate", default="tpu-pool",
                    help=f"one of {api.available_substrates()}")
    ap.add_argument("--solver", default=None,
                    help=f"placement solver, one of {sorted(api.SOLVERS)}")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    print(f"arch={canonical(args.arch)} ({cfg.n_layers}L d={cfg.d_model}, "
          f"reduced config) engine={args.engine}")

    if args.engine == "batch":
        eng = DecodeEngine(cfg, params, max_batch=4, max_len=64)
        for r in range(args.requests):
            eng.submit(Request(rid=r, prompt=[1 + r, 2, 3],
                               max_new_tokens=args.max_new_tokens))
        done = eng.run_until_done()
        for req in done:
            print(f"  request {req.rid}: {len(req.out)} tokens "
                  f"{req.out[:8]}")
        return

    over = {"solver": args.solver} if args.solver else {}
    try:
        eng = api.engine(args.substrate, cfg, params, max_batch=4, **over)
    except ValueError as e:
        raise SystemExit(str(e))
    loads = workloads.SCENARIOS[args.scenario][:10]
    print(f"time slice {eng.t_slice_ms:.3f} ms; loads {loads}")
    for i, n in enumerate(loads):
        r = eng.run_slice(min(n, eng.max_batch))
        used = {k: v for k, v in r.report.placement.items() if v}
        print(f"  slice {i:2d} load {n:2d} E={r.report.energy_pj*1e-6:9.2f}"
              f" uJ retier={'y' if r.retiered else 'n'} "
              f"{'ok' if r.report.deadline_met else 'MISS'} {used}")
    print(f"total {eng.energy_uj():.1f} uJ, "
          f"{eng.deadline_misses()} deadline misses")


if __name__ == "__main__":
    main()
