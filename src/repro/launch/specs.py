"""ShapeDtypeStruct input specs for every (architecture x shape) cell.

``input_specs()`` returns weak-type-correct, shardable stand-ins - no device
allocation ever happens in the dry-run path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.common import ModelConfig

PyTree = Any

# shape id -> (seq_len, global_batch, step kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def cell_is_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (assignment rule)."""
    if shape == "long_500k" and not cfg.is_subquadratic:
        return False, ("skipped: pure full-attention arch at 512k context "
                       "(assignment rule; noted in DESIGN.md)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, seq: int, batch: int) -> PyTree:
    """Batch pytree spec for one train/prefill step."""
    s_text = seq - (cfg.n_prefix_embeds or 0)
    out = {
        "tokens": _sds((batch, s_text), jnp.int32),
        "labels": _sds((batch, s_text), jnp.int32),
    }
    if cfg.n_prefix_embeds:
        out["prefix_embeds"] = _sds((batch, cfg.n_prefix_embeds,
                                     cfg.d_model), cfg.dtype)
    if cfg.is_encdec:
        out["enc_frames"] = _sds(
            (batch, max(seq // cfg.enc_len_divisor, 1), cfg.d_model),
            cfg.dtype)
    return out


def abstract_params(cfg: ModelConfig, serve_dtype=None) -> PyTree:
    """Abstract param tree; ``serve_dtype`` casts float leaves (inference
    residency format - bf16 serving halves HBM bytes vs f32 master)."""
    tree = jax.eval_shape(lambda k: lm.init_lm(k, cfg),
                          jax.random.PRNGKey(0))
    if serve_dtype is None:
        return tree
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, serve_dtype if jnp.issubdtype(x.dtype, jnp.floating)
            else x.dtype), tree)


def abstract_decode_state(cfg: ModelConfig, batch: int, max_len: int
                          ) -> PyTree:
    if cfg.is_encdec:
        enc_len = max(max_len // cfg.enc_len_divisor, 1)
        enc = _sds((batch, enc_len, cfg.d_model), cfg.dtype)
        return jax.eval_shape(
            lambda e: lm.init_decode_state(cfg, batch, max_len, enc_out=e),
            enc)
    return jax.eval_shape(
        lambda: lm.init_decode_state(cfg, batch, max_len))


def decode_token_specs(batch: int) -> Tuple[PyTree, PyTree]:
    return _sds((batch,), jnp.int32), _sds((), jnp.int32)


def dryrun_config(cfg: ModelConfig, mesh=None) -> ModelConfig:
    """Full config tuned for lowering: bf16, scanned stacks, remat on;
    MoE dispatch blocked by the mesh's data-parallel extent and activation
    batch dims pinned to the DP axes."""
    nb = 1
    dp_axes = []
    if mesh is not None:
        for ax in ("pod", "data"):
            if ax in mesh.shape:
                nb *= mesh.shape[ax]
                dp_axes.append(ax)
    return dataclasses.replace(cfg, dtype=jnp.bfloat16, scan_layers=True,
                               remat=True, moe_dispatch_blocks=nb,
                               act_dp_axes=tuple(dp_axes) or None)
