"""Registry-wide substrate smoke: ``python -m repro.launch.substrate_smoke``.

Iterates every name in the substrate registry (``api.list_substrates()``)
and, for each, resolves the default workload, builds the placement LUT
through the substrate's default solver and runs one scheduler slice - the
minimum end-to-end exercise of a registry entry. CI runs this as the
``substrate-smoke`` job so a broken registration (bad constants, an arch
the solvers cannot handle, a workload mapping that raises) fails the
build instead of shipping silently.

    PYTHONPATH=src python -m repro.launch.substrate_smoke
    PYTHONPATH=src python -m repro.launch.substrate_smoke --only gpu
"""
from __future__ import annotations

import argparse
import time
import traceback

from repro import api


def smoke_one(name: str, *, lut_points: int = 8, n_tasks: int = 2) -> dict:
    """LUT build + one scheduler slice for one registry entry."""
    sub = api.substrate(name)
    model = sub.model_spec()
    t_slice_ns = sub.default_t_slice_ns(model)
    lut = sub.build_lut(model, t_slice_ns=t_slice_ns, n_points=lut_points)
    n_feasible = sum(e.feasible for e in lut.entries)
    if not n_feasible:
        raise RuntimeError("LUT has no feasible entries")
    sched = api.scheduler(sub, model, t_slice_ns=t_slice_ns,
                          lut_points=lut_points)
    rep = sched.step(n_tasks)
    if rep.n_tasks != n_tasks or not rep.energy_pj > 0:
        raise RuntimeError(f"bad slice report: {rep}")
    return {"substrate": name, "model": model.name,
            "t_slice_us": t_slice_ns / 1e3,
            "lut_feasible": n_feasible, "lut_entries": len(lut.entries),
            "slice_energy_pj": rep.energy_pj,
            "deadline_met": rep.deadline_met}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", default=None,
                    help="run only substrates whose name contains this")
    ap.add_argument("--lut-points", type=int, default=8)
    ap.add_argument("--tasks", type=int, default=2)
    args = ap.parse_args(argv)

    names = [n for n in api.list_substrates()
             if not args.only or args.only in n]
    if not names:
        raise SystemExit(f"no registered substrate matches {args.only!r}")
    failures = []
    for name in names:
        t0 = time.perf_counter()
        try:
            s = smoke_one(name, lut_points=args.lut_points,
                          n_tasks=args.tasks)
            print(f"{name:18s} ok   model={s['model']:24s} "
                  f"T={s['t_slice_us']:10.2f}us "
                  f"lut={s['lut_feasible']}/{s['lut_entries']} "
                  f"E={s['slice_energy_pj']:.3e}pJ "
                  f"({time.perf_counter() - t0:.2f}s)")
        except Exception as e:
            failures.append(name)
            print(f"{name:18s} FAIL {e!r}")
            traceback.print_exc()
    print(f"\n{len(names) - len(failures)}/{len(names)} substrates ok")
    if failures:
        raise SystemExit(f"substrate smoke failed for: {failures}")


if __name__ == "__main__":
    main()
