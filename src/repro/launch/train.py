"""Training launcher: ``python -m repro.launch.train --arch <id>``.

On this CPU container it trains the reduced (smoke) config of the chosen
architecture end-to-end with the full substrate: synthetic data, AdamW,
async atomic checkpoints, SIGTERM-preemption safety and resume. On real
hardware the same driver takes ``--full`` to use the assigned config with
the mesh/sharding rules exercised by the dry-run.
"""
from __future__ import annotations

import argparse
import signal


from repro.configs import ARCH_IDS, canonical, get_config, get_smoke_config
from repro.data.synthetic import DataConfig
from repro.launch.specs import dryrun_config
from repro.optim.adamw import OptimizerConfig
from repro.train.step import default_optimizer_kind
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b",
                    help=f"one of {ARCH_IDS}")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (requires a pod)")
    args = ap.parse_args()

    cfg = (dryrun_config(get_config(args.arch))
           if args.full else get_smoke_config(args.arch))
    print(f"arch={canonical(args.arch)} layers={cfg.n_layers} "
          f"d={cfg.d_model} optimizer={default_optimizer_kind(cfg)}")

    trainer = Trainer(
        cfg,
        OptimizerConfig(kind=default_optimizer_kind(cfg), lr=1e-3,
                        warmup_steps=10, total_steps=args.steps),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                   global_batch=args.global_batch),
        TrainerConfig(steps=args.steps, ckpt_every=max(args.steps // 4, 1),
                      ckpt_dir=args.ckpt_dir,
                      grad_compression=args.compress_grads))

    # preemption safety: SIGTERM checkpoints at the next step boundary
    signal.signal(signal.SIGTERM, lambda *_: trainer.request_stop())
    if trainer.maybe_resume():
        print(f"resumed at step {trainer.step}")

    out = trainer.run()
    print(f"loss {out['first_loss']:.4f} -> {out['final_loss']:.4f} in "
          f"{out['steps']} steps "
          f"({out['median_step_s']*1e3:.0f} ms/step median, "
          f"{out['straggler_steps']} stragglers)")


if __name__ == "__main__":
    main()
