"""Model zoo: configs + pure-function LMs for all assigned architectures."""
from repro.models.common import ModelConfig, reduced
from repro.models import lm

__all__ = ["ModelConfig", "reduced", "lm"]
