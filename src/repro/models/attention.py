"""Grouped-query attention with RoPE variants, local windows, KV caches and
encoder-decoder cross attention. Pure functions over explicit param dicts."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, apply_rope, dense_init, split_keys

KVCache = Dict[str, jnp.ndarray]   # {"k": (B,S,KV,hd), "v": ..., "pos": ()}


def init_attention(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    hd, d = cfg.hd, cfg.d_model
    ks = split_keys(key, ["q", "k", "v", "o"])
    p = {
        "wq": dense_init(ks["q"], (d, cfg.n_heads * hd)),
        "wk": dense_init(ks["k"], (d, cfg.n_kv_heads * hd)),
        "wv": dense_init(ks["v"], (d, cfg.n_kv_heads * hd)),
        "wo": dense_init(ks["o"], (cfg.n_heads * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hd = cfg.hd
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_kind)
    k = apply_rope(k, positions, cfg.rope_kind)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd); GQA via head grouping."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    q = q.reshape(B, Sq, KV, g, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(hd))
    if mask is not None:
        logits = jnp.where(mask, logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, H * hd)


def _causal_mask(Sq: int, Sk: int, window: Optional[int] = None):
    """(1,1,1,Sq,Sk) boolean mask; window => local (sliding) attention."""
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m[None, None, None]


# sequences at or above this length take the O(S)-memory chunked path
CHUNKED_ATTN_THRESHOLD = 1024
Q_CHUNK = 512
K_CHUNK = 512   # == Q_CHUNK so the causal diagonal is a single chunk pair


def _chunked_causal_sdpa(q, k, v, cfg: ModelConfig, q_chunk: int,
                         k_chunk: int, causal: bool = True):
    """Flash-style online-softmax attention, O(S) memory, pure jnp.

    Outer scan over query chunks, inner scan over key chunks with running
    (max, denom, acc) carries in fp32. Handles causal + GQA.

    Masking is chunk-relative: chunk pairs are fully-visible (j < i),
    diagonal (one shared (c, c) triangular additive mask) or fully masked
    (scalar select) - per-pair boolean tensors would be hoisted out of the
    scan by XLA into O(B * S * c) pred temps (observed 0.5 GiB/device on
    the 4k cells before this formulation).
    """
    assert q_chunk == k_chunk
    c = q_chunk
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    assert causal is False or Sq == Sk
    KV = k.shape[2]
    g = H // KV
    nq, n = Sq // c, Sk // c
    qc = q.reshape(B, nq, c, KV, g, hd).transpose(1, 0, 3, 4, 2, 5)
    kc = k.reshape(B, n, c, KV, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, n, c, KV, hd).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    # single chunk-invariant additive mask for the diagonal pair
    tri = jnp.where(jnp.arange(c)[None, :] <= jnp.arange(c)[:, None],
                    0.0, -1e30).astype(jnp.float32)

    def q_step(_, qi_idx):
        qi, iq = qi_idx                       # (B,KV,g,c,hd), ()

        # checkpointed: the scan's backward otherwise saves the (c, c)
        # probability block of EVERY k-step => O(S^2) residuals (observed
        # ~45 GiB/device at 7k width). Recomputing scores per block is the
        # classic flash-attention backward.
        @jax.checkpoint
        def k_step(carry, kj_idx):
            m, denom, acc = carry
            kj, vj, jk = kj_idx
            s = jnp.einsum("bkgqh,bksh->bkgqs", qi.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            if causal:
                # j < i: visible; j == i: triangular; j > i: masked
                s = s + jnp.where(jk == iq, 1.0, 0.0) * tri
                s = s + jnp.where(jk <= iq, 0.0, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p_ = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            denom_new = denom * corr + p_.sum(axis=-1)
            acc_new = (acc * corr[..., None]
                       + jnp.einsum("bkgqs,bksh->bkgqh", p_,
                                    vj.astype(jnp.float32)))
            return (m_new, denom_new, acc_new), None

        init = (jnp.full((B, KV, g, c), -1e30, jnp.float32),
                jnp.zeros((B, KV, g, c), jnp.float32),
                jnp.zeros((B, KV, g, c, hd), jnp.float32))
        (m, denom, acc), _ = jax.lax.scan(
            k_step, init, (kc, vc, jnp.arange(n)))
        out = acc / jnp.maximum(denom, 1e-30)[..., None]
        return None, out

    _, outs = jax.lax.scan(q_step, None, (qc, jnp.arange(nq)))
    # (nq, B, KV, g, c, hd) -> (B, Sq, H*hd)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H * hd)
    return out.astype(q.dtype)


def _local_windowed_sdpa(q, k, v, cfg: ModelConfig, q_chunk: int):
    """Sliding-window attention: per q-chunk, attend to the preceding
    ``window`` keys only - O(S * window) compute, exact."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    W = cfg.local_window
    nq = S // q_chunk
    span = W + q_chunk
    # left-pad keys so every chunk slices a static [span] window
    kp = jnp.pad(k, ((0, 0), (W, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (W, 0), (0, 0), (0, 0)))
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qc = q.reshape(B, nq, q_chunk, KV, g, hd).transpose(1, 0, 3, 4, 2, 5)

    # chunk-invariant window mask: relative offset k-q = (kk-W) - qq is the
    # same for every chunk, so one (cq, span) additive mask suffices; only
    # the left-boundary validity (k_pos >= 0) varies per chunk, and that is
    # a cheap per-chunk (span,) vector.
    qq = jnp.arange(q_chunk)[:, None]
    kk = jnp.arange(span)[None, :]
    rel = (kk - W) - qq
    win_mask = jnp.where((rel <= 0) & (rel > -W), 0.0,
                         -1e30).astype(jnp.float32)

    @jax.checkpoint
    def q_step(_, qi_idx):
        qi, iq = qi_idx
        start = iq * q_chunk
        kj = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        kj = kj.transpose(0, 2, 1, 3)      # (B,KV,span,hd)
        vj = vj.transpose(0, 2, 1, 3)
        s = jnp.einsum("bkgqh,bksh->bkgqs", qi.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale
        valid = jnp.where(start - W + jnp.arange(span) >= 0, 0.0,
                          -1e30).astype(jnp.float32)
        s = s + win_mask + valid[None, :]
        p_ = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqs,bksh->bkgqh", p_, vj.astype(jnp.float32))
        return None, out

    _, outs = jax.lax.scan(q_step, None, (qc, jnp.arange(nq)))
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H * hd)
    return out.astype(q.dtype)


def attention(p, x, cfg: ModelConfig, positions, *,
              layer_kind: str = "attn") -> jnp.ndarray:
    """Full-sequence (training / prefill) self attention.

    Long sequences use the O(S)-memory chunked path (flash-style online
    softmax for causal-full, exact windowed slicing for local attention).
    """
    q, k, v = _project_qkv(p, x, cfg, positions)
    S = x.shape[1]
    local = cfg.attn_kind == "local"
    if S >= CHUNKED_ATTN_THRESHOLD and S % Q_CHUNK == 0:
        if local and cfg.local_window < S and S % K_CHUNK == 0:
            out = _local_windowed_sdpa(q, k, v, cfg, Q_CHUNK)
        elif not local and S % K_CHUNK == 0:
            out = _chunked_causal_sdpa(q, k, v, cfg, Q_CHUNK, K_CHUNK)
        else:
            mask = _causal_mask(S, S, cfg.local_window if local else None)
            out = _sdpa(q, k, v, mask, cfg)
    else:
        mask = _causal_mask(S, S, cfg.local_window if local else None)
        out = _sdpa(q, k, v, mask, cfg)
    return out @ p["wo"].astype(x.dtype)


def encoder_attention(p, x, cfg: ModelConfig, positions) -> jnp.ndarray:
    """Bidirectional self-attention (encoder side)."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    S = x.shape[1]
    if (S >= CHUNKED_ATTN_THRESHOLD and S % Q_CHUNK == 0
            and S % K_CHUNK == 0):
        out = _chunked_causal_sdpa(q, k, v, cfg, Q_CHUNK, K_CHUNK,
                                   causal=False)
    else:
        out = _sdpa(q, k, v, None, cfg)
    return out @ p["wo"].astype(x.dtype)


def init_cross_attention(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    return init_attention(key, cfg)


def cross_attention(p, x, enc_out, cfg: ModelConfig) -> jnp.ndarray:
    """Decoder cross attention over encoder outputs (no RoPE, no mask)."""
    B, Sq, _ = x.shape
    Sk = enc_out.shape[1]
    hd = cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, Sq, cfg.n_heads, hd)
    k = (enc_out @ p["wk"].astype(x.dtype)).reshape(B, Sk, cfg.n_kv_heads, hd)
    v = (enc_out @ p["wv"].astype(x.dtype)).reshape(B, Sk, cfg.n_kv_heads, hd)
    if (max(Sq, Sk) >= CHUNKED_ATTN_THRESHOLD and Sq % Q_CHUNK == 0
            and Sk % K_CHUNK == 0):
        out = _chunked_causal_sdpa(q, k, v, cfg, Q_CHUNK, K_CHUNK,
                                   causal=False)
    else:
        out = _sdpa(q, k, v, None, cfg)
    return out @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# decode path (KV cache)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype) -> KVCache:
    if cfg.attn_kind == "local":
        max_len = min(max_len, cfg.local_window)
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
    }


def attention_decode(p, x, cfg: ModelConfig, cache: KVCache,
                     pos: jnp.ndarray) -> Tuple[jnp.ndarray, KVCache]:
    """One-token decode. x: (B,1,d); pos: () or (B,) int32 absolute
    position(s) - a vector gives every batch row its own position (slot
    continuous batching, where requests start at different times).

    Local attention uses a ring buffer of size ``local_window``; full
    attention appends at ``pos``.
    """
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1
    positions = (pos[:, None] if per_row
                 else jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32))
    q, k, v = _project_qkv(p, x, cfg, positions)
    C = cache["k"].shape[1]
    slot = (pos % C).astype(jnp.int32)
    # masked-select write instead of dynamic-update-slice: a DUS with a
    # dynamic index on the sequence-sharded cache dim makes SPMD all-gather
    # the whole cache every layer (measured 3.1 GiB/step on qwen decode);
    # the elementwise select partitions trivially (EXPERIMENTS.md SS.Perf).
    idx = jnp.arange(C, dtype=jnp.int32)
    if per_row:
        sel = (idx[None, :] == slot[:, None])[:, :, None, None]
    else:
        sel = (idx == slot)[None, :, None, None]
    new_k = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
    new_v = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
    # valid = entries written so far and (for local) within the window
    if cfg.attn_kind == "local":
        if per_row:
            valid = (idx[None, :] <= slot[:, None]) | (pos[:, None] >= C)
        else:
            valid = (idx <= slot) | (pos >= C)  # ring buffer full => all
    else:
        valid = idx[None, :] <= pos[:, None] if per_row else idx <= pos
    mask = (valid[:, None, None, None, :] if per_row
            else valid[None, None, None, None, :])
    out = _sdpa(q, new_k, new_v, mask, cfg)
    out = out @ p["wo"].astype(x.dtype)
    return out, {"k": new_k, "v": new_v}


def cross_attention_decode(p, x, enc_out, cfg: ModelConfig) -> jnp.ndarray:
    return cross_attention(p, x, enc_out, cfg)
