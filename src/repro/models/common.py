"""Model configuration and shared building blocks for the architecture zoo."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config covers all ten assigned architectures (DESIGN.md SS.5)."""

    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention
    attn_kind: str = "full"      # full | local
    local_window: int = 2048
    rope_kind: str = "full"      # full | 2d | none
    qkv_bias: bool = False
    mlp_act: str = "swiglu"      # swiglu | geglu | gelu

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_dense_ff: int = 0        # arctic: dense residual MLP alongside MoE
    moe_dispatch_blocks: int = 1  # launcher sets = data-parallel size

    # hybrid / ssm block pattern, repeated through depth:
    #   "attn" | "rglru" | "mlstm" | "slstm"
    block_pattern: Tuple[str, ...] = ("attn",)

    # enc-dec
    n_encoder_layers: int = 0    # >0 => encoder-decoder
    enc_len_divisor: int = 1     # encoder frames = seq_len // divisor

    # modality frontend stub: none | patch | frames
    frontend: str = "none"
    n_prefix_embeds: int = 0     # vlm: patch embeddings prepended

    # numerics / compile hygiene
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scan_layers: bool = True
    remat: bool = True
    # activation batch-dim sharding hint (mesh axis names); set by the
    # launcher. Without it SPMD flip-flops layouts between FSDP-sharded
    # params and replicates multi-GiB FFN transients.
    act_dp_axes: Optional[Tuple[str, ...]] = None

    # serving: HH-PIM tier fractions (hp_bf16, hp_int8, lp_bf16, lp_int8)
    tier_fractions: Optional[Tuple[float, float, float, float]] = None

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def is_subquadratic(self) -> bool:
        """True if decode state does not grow linearly with full context
        (SSM / hybrid-with-local-attention)."""
        return all(k in ("rglru", "mlstm", "slstm") or
                   (k == "attn" and self.attn_kind == "local")
                   for k in self.block_pattern)

    def pattern_for_depth(self) -> Tuple[str, ...]:
        p = []
        while len(p) < self.n_layers:
            p.extend(self.block_pattern)
        return tuple(p[: self.n_layers])


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(kv, 4)
    base = dict(
        n_layers=min(cfg.n_layers, len(cfg.block_pattern) * 2),
        d_model=64, n_heads=heads, n_kv_heads=kv, d_ff=128,
        vocab_size=512, head_dim=16, local_window=16,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        moe_dense_ff=64 if cfg.moe_dense_ff else 0,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        n_prefix_embeds=4 if cfg.n_prefix_embeds else 0,
        dtype=jnp.float32, scan_layers=False, remat=False,
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)


# ---------------------------------------------------------------------------
# numerics helpers
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def _rope_freqs(hd: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               kind: str = "full") -> jnp.ndarray:
    """Rotary embedding. x: (B, S, H, hd); positions: (B, S).

    kind="full": rotate all hd dims; kind="2d": ChatGLM-style - rotate only
    the first half of head_dim (two-dimensional RoPE), pass the rest through.
    """
    if kind == "none":
        return x
    hd = x.shape[-1]
    rot = hd if kind == "full" else hd // 2
    freqs = _rope_freqs(rot)                                # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1).astype(x.dtype)


def dense_init(key, shape, in_axis: int = 0) -> jnp.ndarray:
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32)
            / math.sqrt(fan_in)).astype(jnp.float32)


def split_keys(key, names) -> Dict[str, jax.Array]:
    ks = jax.random.split(key, len(names))
    return dict(zip(names, ks))


def replicate_for_gather(table: jnp.ndarray, cfg: "ModelConfig"
                         ) -> jnp.ndarray:
    """Explicitly all-gather a (sharded) lookup table before a token gather.

    Gathering from a d_model-sharded table and resharding the result trips
    an XLA SPMD dynamic-slice verifier bug (observed on the 16x16 mesh);
    resharding the parameter first is one clean all-gather instead."""
    if cfg.act_dp_axes is None:
        return table
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(table, P())
    except (ValueError, TypeError):
        return table


def shard_activations(x: jnp.ndarray, cfg: "ModelConfig",
                      *trailing) -> jnp.ndarray:
    """Constrain an activation's batch dim to the DP axes (no-op outside a
    mesh or when the launcher did not set ``act_dp_axes``)."""
    if cfg.act_dp_axes is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = (cfg.act_dp_axes,) + tuple(trailing) + \
        (None,) * (x.ndim - 1 - len(trailing))
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, TypeError):
        return x
