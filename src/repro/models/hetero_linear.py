"""Tiered linear layers: the HH-PIM storage spaces realized on TPU.

A weight matrix is split column-wise into four segments
(hp_bf16 | hp_int8 | lp_bf16 | lp_int8) per the placement LUT. bf16
segments are the "SRAM" tier (full-bandwidth reads); int8 segments are the
"MRAM" tier (half the HBM bytes, W8A8 through the pim_mac kernel). The
hp/lp pools differ in chips+clock in the energy model; functionally the
math is identical, so outputs are placement-invariant up to int8
quantization error.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from repro.kernels.pim_mac.ops import pim_matmul
from repro.quant.int8 import quantize_activations, quantize_per_channel

SPACES = ("hp_bf16", "hp_int8", "lp_bf16", "lp_int8")


def split_weight(w: jnp.ndarray, counts: Dict[str, int]) -> Dict[str, dict]:
    """Split (d_in, d_out) columns into tier segments per `counts`
    (columns per space, summing to d_out). int8 tiers store (q, scale)."""
    assert sum(counts.values()) == w.shape[1], (counts, w.shape)
    segs: Dict[str, dict] = {}
    off = 0
    for name in SPACES:
        n = counts.get(name, 0)
        seg = w[:, off:off + n]
        off += n
        if n == 0:
            segs[name] = {"empty": True}
        elif name.endswith("int8"):
            q, s = quantize_per_channel(seg, axis=0)
            segs[name] = {"q": q, "scale": s}
        else:
            segs[name] = {"w": seg.astype(jnp.bfloat16)}
    return segs


def tiered_matmul(x: jnp.ndarray, segs: Dict[str, dict],
                  backend: str = "ref") -> jnp.ndarray:
    """x: (..., d_in) -> (..., d_out), concatenating tier outputs."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    outs = []
    xq = sx = None
    for name in SPACES:
        seg = segs[name]
        if seg.get("empty"):
            continue
        if name.endswith("int8"):
            if xq is None:
                xq, sx = quantize_activations(x2)
            y = pim_matmul(xq, seg["q"], sx, seg["scale"],
                           backend=backend, out_dtype=jnp.float32)
        else:
            y = (x2.astype(jnp.bfloat16) @ seg["w"]).astype(jnp.float32)
        outs.append(y)
    y = jnp.concatenate(outs, axis=-1)
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)


def fractions_to_counts(d_out: int, placement: Dict[str, int],
                        total: int) -> Dict[str, int]:
    """Scale a global weight-count placement to one matrix's columns."""
    counts = {}
    acc = 0
    for name in SPACES[:-1]:
        c = int(round(d_out * placement.get(name, 0) / max(total, 1)))
        c = min(c, d_out - acc)
        counts[name] = c
        acc += c
    counts[SPACES[-1]] = d_out - acc
    return counts
