"""Tiered linear layers: the HH-PIM storage spaces realized on TPU.

A weight matrix is split column-wise into per-tier segments according to
the placement LUT. The legacy (tpu/gpu pool) mapping is four segments
(hp_bf16 | hp_int8 | lp_bf16 | lp_int8): bf16 segments are the "SRAM"
tier (full-bandwidth reads); int8 segments are the "MRAM" tier (half
the HBM bytes, W8A8 through the pim_mac kernel). The hp/lp pools differ
in chips+clock in the energy model; functionally the math is identical,
so outputs are placement-invariant up to int8 quantization error.

A substrate can supply its own tier naming and formats via the
``formats`` mapping (see ``Substrate.tier_plan``): the CXL substrates
use int8/int8 tier pairs (e.g. hp_ddr_int8 | hp_cxl_int8 | ...), where
a placement change moves real weight columns between segments without
a format change, and the three-tier ``cxl-tier-3`` splits into one
int8 segment per pool (hbm_int8 | ddr_int8 | cxl_int8).
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Sequence

import jax.numpy as jnp

from repro.kernels.pim_mac.ops import pim_matmul
from repro.quant.int8 import quantize_activations, quantize_per_channel

#: legacy tpu/gpu pool tier order; also the default split order
SPACES = ("hp_bf16", "hp_int8", "lp_bf16", "lp_int8")


def split_weight(w: jnp.ndarray, counts: Dict[str, int],
                 formats: Optional[Mapping[str, str]] = None
                 ) -> Dict[str, dict]:
    """Split (d_in, d_out) columns into tier segments per ``counts``
    (columns per tier, summing to d_out). int8 tiers store (q, scale).

    Without ``formats`` the legacy 4-tier naming applies (``SPACES``
    order, ``*_int8`` names quantized). With ``formats`` (tier ->
    "bf16" | "int8") the split follows ``counts``' own (insertion)
    order - the substrate's ``tier_plan`` order."""
    assert sum(counts.values()) == w.shape[1], (counts, w.shape)
    order = SPACES if formats is None else tuple(counts)
    segs: Dict[str, dict] = {}
    off = 0
    for name in order:
        n = counts.get(name, 0)
        seg = w[:, off:off + n]
        off += n
        fmt = (("int8" if name.endswith("int8") else "bf16")
               if formats is None else formats[name])
        if n == 0:
            segs[name] = {"empty": True}
        elif fmt == "int8":
            q, s = quantize_per_channel(seg, axis=0)
            segs[name] = {"q": q, "scale": s}
        else:
            segs[name] = {"w": seg.astype(jnp.bfloat16)}
    return segs


def tiered_matmul(x: jnp.ndarray, segs: Dict[str, dict],
                  backend: str = "ref") -> jnp.ndarray:
    """x: (..., d_in) -> (..., d_out), concatenating tier outputs in
    the segments' split order (the dict's insertion order)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    outs = []
    xq = sx = None
    for name, seg in segs.items():
        if seg.get("empty"):
            continue
        if "q" in seg:                       # int8 tier (W8A8 kernel)
            if xq is None:
                xq, sx = quantize_activations(x2)
            y = pim_matmul(xq, seg["q"], sx, seg["scale"],
                           backend=backend, out_dtype=jnp.float32)
        else:                                # bf16 tier
            y = (x2.astype(jnp.bfloat16) @ seg["w"]).astype(jnp.float32)
        outs.append(y)
    y = jnp.concatenate(outs, axis=-1)
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)


def fractions_to_counts(d_out: int, placement: Dict[str, int],
                        total: int,
                        order: Sequence[str] = SPACES) -> Dict[str, int]:
    """Scale a global weight-count placement to one matrix's columns;
    ``order`` is the tier split order (last tier absorbs rounding)."""
    counts = {}
    acc = 0
    for name in order[:-1]:
        c = int(round(d_out * placement.get(name, 0) / max(total, 1)))
        c = min(c, d_out - acc)
        counts[name] = c
        acc += c
    counts[order[-1]] = d_out - acc
    return counts
