"""Full language models assembled from the block zoo.

Supports every assigned architecture family:
  dense / moe decoder LMs (GQA attention + [Swi/Ge]GLU or MoE FFN),
  hybrid stacks (RG-LRU + local attention, RecurrentGemma-style),
  ssm stacks (mLSTM/sLSTM, xLSTM-style),
  encoder-decoder (Seamless-style; frame-embedding frontend stub),
  vlm (Pixtral-style; patch-embedding frontend stub prepended to text).

Homogeneous pattern groups are stacked and scanned (``lax.scan``) so HLO
size is O(1) in depth; heterogeneous tails run unscanned. Remat wraps each
block. Everything is a pure function over an explicit param pytree, so
``jax.eval_shape`` gives abstract params for the dry-run without ever
materializing a 480 B-parameter model.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import recurrent as rec_lib
from repro.models.common import (ModelConfig, dense_init,
                                 replicate_for_gather, rms_norm,
                                 shard_activations, split_keys)
from repro.models.mlp import init_mlp_cfg, mlp_cfg

PyTree = Any


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _has_ffn(cfg: ModelConfig, kind: str) -> bool:
    if kind in ("mlstm", "slstm"):
        return cfg.d_ff > 0
    return True


def init_block(key, cfg: ModelConfig, kind: str,
               cross: bool = False) -> PyTree:
    ks = split_keys(key, ["mix", "ffn", "cross"])
    p: Dict[str, PyTree] = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if kind == "attn":
        p["mix"] = attn_lib.init_attention(ks["mix"], cfg)
    elif kind == "rglru":
        p["mix"] = rec_lib.init_rglru(ks["mix"], cfg)
    elif kind == "mlstm":
        p["mix"] = rec_lib.init_mlstm(ks["mix"], cfg)
    elif kind == "slstm":
        p["mix"] = rec_lib.init_slstm(ks["mix"], cfg)
    else:
        raise ValueError(kind)
    if cross:
        p["ln_cross"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["cross"] = attn_lib.init_cross_attention(ks["cross"], cfg)
    if _has_ffn(cfg, kind):
        p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.n_experts and kind == "attn":
            p["ffn"] = moe_lib.init_moe(ks["ffn"], cfg)
        else:
            p["ffn"] = init_mlp_cfg(ks["ffn"], cfg)
    return p


def apply_block(p: PyTree, x: jnp.ndarray, cfg: ModelConfig, kind: str, *,
                positions, enc_out=None, causal: bool = True
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block application. Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "attn":
        if causal:
            h = attn_lib.attention(p["mix"], h, cfg, positions)
        else:
            h = attn_lib.encoder_attention(p["mix"], h, cfg, positions)
    elif kind == "rglru":
        h = rec_lib.rglru_block(p["mix"], h, cfg)
    elif kind == "mlstm":
        h = rec_lib.mlstm_block(p["mix"], h, cfg)
    elif kind == "slstm":
        h = rec_lib.slstm_block(p["mix"], h, cfg)
    x = x + h
    if "cross" in p:
        h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        x = x + attn_lib.cross_attention(p["cross"], h, enc_out, cfg)
    if "ffn" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.n_experts and kind == "attn":
            aux = moe_lib.aux_load_balance_loss(p["ffn"], h, cfg)
            h = moe_lib.moe(p["ffn"], h, cfg)
        else:
            h = mlp_cfg(p["ffn"], h, cfg)
        x = x + h
    return x, aux


def apply_block_decode(p: PyTree, x: jnp.ndarray, cfg: ModelConfig,
                       kind: str, state: PyTree, *, pos, enc_out=None
                       ) -> Tuple[jnp.ndarray, PyTree]:
    """One-token block application with recurrent/KV state."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "attn":
        h, new_state = attn_lib.attention_decode(p["mix"], h, cfg, state, pos)
    elif kind == "rglru":
        h, new_state = rec_lib.rglru_decode(p["mix"], h, cfg, state)
    elif kind == "mlstm":
        h, new_state = rec_lib.mlstm_decode(p["mix"], h, cfg, state)
    elif kind == "slstm":
        h, new_state = rec_lib.slstm_decode(p["mix"], h, cfg, state)
    x = x + h
    if "cross" in p:
        h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        x = x + attn_lib.cross_attention(p["cross"], h, enc_out, cfg)
    if "ffn" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.n_experts and kind == "attn":
            h = moe_lib.moe(p["ffn"], h, cfg)
        else:
            h = mlp_cfg(p["ffn"], h, cfg)
        x = x + h
    return x, new_state


def init_block_state(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype) -> PyTree:
    if kind == "attn":
        return attn_lib.init_kv_cache(cfg, batch, max_len, dtype)
    if kind == "rglru":
        return rec_lib.init_rglru_state(cfg, batch, dtype)
    if kind == "mlstm":
        return rec_lib.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return rec_lib.init_slstm_state(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stack layout: scanned groups + tail
# ---------------------------------------------------------------------------


def _stack_layout(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...],
                                             Tuple[str, ...]]:
    """Returns (n_groups, period_kinds, tail_kinds)."""
    pattern = cfg.pattern_for_depth()
    period = cfg.block_pattern
    if not cfg.scan_layers:
        return 0, (), pattern
    n_groups = cfg.n_layers // len(period)
    tail = pattern[n_groups * len(period):]
    if n_groups < 2:        # scanning 0/1 group is pointless
        return 0, (), pattern
    return n_groups, period, tail


def _init_stack(key, cfg: ModelConfig, cross: bool) -> PyTree:
    n_groups, period, tail = _stack_layout(cfg)
    out: Dict[str, PyTree] = {}
    if n_groups:
        def init_group(k):
            gk = split_keys(k, [f"p{i}" for i in range(len(period))])
            return {f"p{i}": init_block(gk[f"p{i}"], cfg, kind, cross)
                    for i, kind in enumerate(period)}
        keys = jax.random.split(key, n_groups + 1)
        stacked = jax.vmap(init_group)(keys[:n_groups])
        out["scan"] = stacked
        key = keys[-1]
    tkeys = jax.random.split(key, max(len(tail), 1))
    for i, kind in enumerate(tail):
        out[f"tail_{i}"] = init_block(tkeys[i], cfg, kind, cross)
    return out


def _apply_stack(params: PyTree, x: jnp.ndarray, cfg: ModelConfig, *,
                 positions, enc_out=None, causal=True
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n_groups, period, tail = _stack_layout(cfg)
    aux_total = jnp.float32(0.0)

    def one_group(carry, gparams):
        x, aux = carry
        for i, kind in enumerate(period):
            blk = functools.partial(apply_block, cfg=cfg, kind=kind,
                                    positions=positions, enc_out=enc_out,
                                    causal=causal)
            if cfg.remat:
                blk = jax.checkpoint(blk)
            x, a = blk(gparams[f"p{i}"], x)
            # constrain OUTSIDE the checkpoint boundary (inside trips the
            # SPMD partitioner's dynamic-slice handling)
            x = shard_activations(x, cfg)
            aux = aux + a
        return (x, aux), None

    x = shard_activations(x, cfg)
    if n_groups:
        (x, aux_total), _ = jax.lax.scan(one_group, (x, aux_total),
                                         params["scan"])
    for i, kind in enumerate(tail):
        blk = functools.partial(apply_block, cfg=cfg, kind=kind,
                                positions=positions, enc_out=enc_out,
                                causal=causal)
        if cfg.remat:
            blk = jax.checkpoint(blk)
        x, a = blk(params[f"tail_{i}"], x)
        x = shard_activations(x, cfg)
        aux_total = aux_total + a
    return x, aux_total


def _init_stack_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype) -> PyTree:
    n_groups, period, tail = _stack_layout(cfg)
    out: Dict[str, PyTree] = {}
    if n_groups:
        def one(_):
            return {f"p{i}": init_block_state(cfg, kind, batch, max_len,
                                              dtype)
                    for i, kind in enumerate(period)}
        out["scan"] = jax.vmap(one)(jnp.arange(n_groups))
    for i, kind in enumerate(tail):
        out[f"tail_{i}"] = init_block_state(cfg, kind, batch, max_len, dtype)
    return out


def _apply_stack_decode(params: PyTree, x: jnp.ndarray, cfg: ModelConfig,
                        state: PyTree, *, pos, enc_out=None
                        ) -> Tuple[jnp.ndarray, PyTree]:
    n_groups, period, tail = _stack_layout(cfg)
    new_state: Dict[str, PyTree] = {}

    def one_group(x, inp):
        gparams, gstate = inp
        gnew = {}
        for i, kind in enumerate(period):
            x, s = apply_block_decode(gparams[f"p{i}"], x, cfg, kind,
                                      gstate[f"p{i}"], pos=pos,
                                      enc_out=enc_out)
            gnew[f"p{i}"] = s
        return x, gnew

    if n_groups:
        x, new_state["scan"] = jax.lax.scan(one_group, x,
                                            (params["scan"], state["scan"]))
    for i, kind in enumerate(tail):
        x, s = apply_block_decode(params[f"tail_{i}"], x, cfg, kind,
                                  state[f"tail_{i}"], pos=pos,
                                  enc_out=enc_out)
        new_state[f"tail_{i}"] = s
    return x, new_state


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ModelConfig) -> PyTree:
    ks = split_keys(key, ["embed", "stack", "enc", "head", "front"])
    params: Dict[str, PyTree] = {
        "embed": dense_init(ks["embed"], (cfg.vocab_size, cfg.d_model),
                            in_axis=1),
        "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
        "stack": _init_stack(ks["stack"], cfg, cross=cfg.is_encdec),
    }
    if cfg.is_encdec:
        params["encoder"] = _init_stack(ks["enc"], _enc_cfg(cfg),
                                        cross=False)
        params["enc_final_ln"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks["head"], (cfg.d_model,
                                                    cfg.vocab_size))
    return params


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, n_layers=cfg.n_encoder_layers,
                               n_experts=0, block_pattern=("attn",))


def _embed_inputs(params, cfg: ModelConfig, tokens, prefix_embeds):
    """Token embedding (+ optional prepended modality embeddings).

    Cast to compute dtype BEFORE the replication constraint (halves the
    all-gather bytes); small token counts gather straight from the sharded
    table (replicating a 256k-row table for a 128-token decode step was a
    measured 2.9 GiB/step all-gather - EXPERIMENTS.md SS.Perf iter 2)."""
    table = params["embed"].astype(cfg.dtype)
    if tokens.size > 4096:
        table = replicate_for_gather(table, cfg)
    x = table[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, positions


def _lm_logits(params, cfg: ModelConfig, x) -> jnp.ndarray:
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.dtype)
    return x @ head


def encode(params, cfg: ModelConfig, enc_frames) -> jnp.ndarray:
    """Encoder for enc-dec models; enc_frames: (B, Se, d) frontend stub."""
    ec = _enc_cfg(cfg)
    B, Se, _ = enc_frames.shape
    positions = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    x, _ = _apply_stack(params["encoder"], enc_frames.astype(cfg.dtype), ec,
                        positions=positions, causal=False)
    return rms_norm(x, params["enc_final_ln"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
            enc_frames=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training/prefill forward. Returns (logits, aux_loss)."""
    enc_out = None
    if cfg.is_encdec:
        assert enc_frames is not None
        enc_out = encode(params, cfg, enc_frames)
    x, positions = _embed_inputs(params, cfg, tokens, prefix_embeds)
    x, aux = _apply_stack(params["stack"], x, cfg, positions=positions,
                          enc_out=enc_out, causal=True)
    return _lm_logits(params, cfg, x), aux


_CE_CHUNK = 512


def forward_hidden(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
                   enc_frames=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Like forward() but stops at the final norm (no vocab projection)."""
    enc_out = None
    if cfg.is_encdec:
        assert enc_frames is not None
        enc_out = encode(params, cfg, enc_frames)
    x, positions = _embed_inputs(params, cfg, tokens, prefix_embeds)
    x, aux = _apply_stack(params["stack"], x, cfg, positions=positions,
                          enc_out=enc_out, causal=True)
    return rms_norm(x, params["final_ln"], cfg.norm_eps), aux


def _chunked_ce(h, head, targets, mask, n_chunks: int) -> jnp.ndarray:
    """Cross-entropy over sequence chunks: the (B, S, vocab) logits tensor
    is never materialized whole (multi-GiB at 256k vocabs); each chunk's
    logits are recomputed in the backward pass (checkpoint)."""
    B, S, d = h.shape
    c = S // n_chunks
    hc = h.reshape(B, n_chunks, c, d).swapaxes(0, 1)
    tc = targets.reshape(B, n_chunks, c).swapaxes(0, 1)
    mc = mask.reshape(B, n_chunks, c).swapaxes(0, 1)

    @jax.checkpoint
    def one(carry, xs):
        hx, tx, mx = xs
        logits = (hx @ head).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tx[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(nll * mx), None

    total, _ = jax.lax.scan(one, jnp.float32(0.0), (hc, tc, mc))
    return total


def loss_fn(params, cfg: ModelConfig, batch) -> Tuple[jnp.ndarray, Dict]:
    """Next-token cross-entropy (text positions only for vlm prefixes)."""
    h, aux = forward_hidden(params, cfg, batch["tokens"],
                            prefix_embeds=batch.get("prefix_embeds"),
                            enc_frames=batch.get("enc_frames"))
    P = 0 if batch.get("prefix_embeds") is None else \
        batch["prefix_embeds"].shape[1]
    h = h[:, P:, :]
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.dtype)
    targets = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(targets.shape, jnp.float32)
    S = h.shape[1]
    n_chunks = S // _CE_CHUNK if S % _CE_CHUNK == 0 and S > _CE_CHUNK else 1
    if n_chunks > 1:
        total_nll = _chunked_ce(h, head, targets, mask, n_chunks)
    else:
        logits = (h @ head).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        total_nll = jnp.sum(nll * mask)
    loss = total_nll / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux,
                   "tokens": jnp.sum(mask)}


# -- decode -----------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      *, enc_out=None) -> PyTree:
    state = {"layers": _init_stack_state(cfg, batch, max_len, cfg.dtype)}
    if cfg.is_encdec:
        state["enc_out"] = enc_out
    return state


def decode_step(params, cfg: ModelConfig, state, tokens, pos
                ) -> Tuple[jnp.ndarray, PyTree]:
    """One decode step. tokens: (B,) int32; pos: () int32, or (B,) int32
    for per-row positions (slot continuous batching).

    Returns (logits (B, vocab), new_state).
    """
    x = params["embed"].astype(cfg.dtype)[tokens][:, None, :]
    enc_out = state.get("enc_out")
    x, new_layers = _apply_stack_decode(params["stack"], x, cfg,
                                        state["layers"], pos=pos,
                                        enc_out=enc_out)
    logits = _lm_logits(params, cfg, x)[:, 0, :]
    new_state = dict(state)
    new_state["layers"] = new_layers
    return logits, new_state


def prefill(params, cfg: ModelConfig, tokens, *, max_len: int,
            prefix_embeds=None, enc_frames=None
            ) -> Tuple[jnp.ndarray, PyTree]:
    """Process a prompt and build a decode state by stepping (reference
    implementation used by tests; production serving uses forward() for
    logits and batch-writes the cache)."""
    B, S = tokens.shape
    enc_out = encode(params, cfg, enc_frames) if cfg.is_encdec else None
    state = init_decode_state(cfg, B, max_len, enc_out=enc_out)
    logits = None
    x, _ = _embed_inputs(params, cfg, tokens, prefix_embeds)
    total = x.shape[1]
    for t in range(total):
        tok_x = x[:, t]
        # re-embedding bypass: feed embeddings directly
        logits, state = _decode_step_embed(params, cfg, state, tok_x,
                                           jnp.int32(t))
    return logits, state


def _decode_step_embed(params, cfg, state, x_embed, pos):
    x = x_embed[:, None, :]
    enc_out = state.get("enc_out")
    x, new_layers = _apply_stack_decode(params["stack"], x, cfg,
                                        state["layers"], pos=pos,
                                        enc_out=enc_out)
    logits = _lm_logits(params, cfg, x)[:, 0, :]
    new_state = dict(state)
    new_state["layers"] = new_layers
    return logits, new_state
