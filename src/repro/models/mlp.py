"""Feed-forward blocks: SwiGLU / GeGLU / GELU MLPs."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, split_keys


def init_mlp(key, d_model: int, d_ff: int, act: str) -> Dict[str, jnp.ndarray]:
    if act in ("swiglu", "geglu"):
        ks = split_keys(key, ["gate", "up", "down"])
        return {
            "w_gate": dense_init(ks["gate"], (d_model, d_ff)),
            "w_up": dense_init(ks["up"], (d_model, d_ff)),
            "w_down": dense_init(ks["down"], (d_ff, d_model)),
        }
    ks = split_keys(key, ["up", "down"])
    return {
        "w_up": dense_init(ks["up"], (d_model, d_ff)),
        "w_down": dense_init(ks["down"], (d_ff, d_model)),
    }


def mlp(p, x: jnp.ndarray, act: str) -> jnp.ndarray:
    dt = x.dtype
    if act in ("swiglu", "geglu"):
        g = x @ p["w_gate"].astype(dt)
        u = x @ p["w_up"].astype(dt)
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        return (g * u) @ p["w_down"].astype(dt)
    u = jax.nn.gelu(x @ p["w_up"].astype(dt))
    return u @ p["w_down"].astype(dt)


def init_mlp_cfg(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    return init_mlp(key, cfg.d_model, cfg.d_ff, cfg.mlp_act)


def mlp_cfg(p, x, cfg: ModelConfig) -> jnp.ndarray:
    return mlp(p, x, cfg.mlp_act)
