"""Mixture-of-Experts layer with block-parallel scatter dispatch.

Dispatch is index-based (per-block cumsum positions + a *batched* scatter
into per-expert slots), NOT a one-hot einsum: a (T,E,C) dispatch matmul
would add O(T^2) fake FLOPs that swamp the roofline (DESIGN.md SS.6).

Sharding design: tokens are grouped into ``moe_dispatch_blocks`` blocks
(the launcher sets this to the data-parallel size). Every scatter/gather is
then *batched over the block dim*, so SPMD keeps them local to the data
shard instead of replicating the slot buffers (the naive global scatter
triggered involuntary full rematerialization - 70+ GiB/device on
arctic-480b). Expert GEMMs carry the expert dim, sharded over "model" (EP).
Real compute = E x C x d x f grouped GEMMs = true MoE FLOPs times the
capacity slack; over-capacity tokens are dropped (GShard-style) with the
residual stream keeping them alive.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig, dense_init, split_keys
from repro.models.mlp import init_mlp, mlp


def _wsc(x, *spec):
    """Sharding hint, applied only when dispatch is mesh-blocked (the
    launcher sets moe_dispatch_blocks > 1 iff running under a mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, TypeError):    # no ambient mesh (tests, CPU path)
        return x


def init_moe(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = split_keys(key, ["router", "gate", "up", "down", "dense"])
    p = {
        "router": dense_init(ks["router"], (d, E)),
        "w_gate": dense_init(ks["gate"], (E, d, f), in_axis=1),
        "w_up": dense_init(ks["up"], (E, d, f), in_axis=1),
        "w_down": dense_init(ks["down"], (E, f, d), in_axis=1),
    }
    if cfg.moe_dense_ff:
        p["dense_mlp"] = init_mlp(ks["dense"], d, cfg.moe_dense_ff,
                                  cfg.mlp_act)
    return p


def _block_capacity(t_block: int, cfg: ModelConfig) -> int:
    c = math.ceil(t_block * cfg.experts_per_token / cfg.n_experts
                  * cfg.moe_capacity_factor)
    return max(4, min(t_block, c))


def moe(p, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.experts_per_token
    nb = cfg.moe_dispatch_blocks
    if T % nb != 0:
        nb = 1
    tb = T // nb                      # tokens per dispatch block
    C = _block_capacity(tb, cfg)
    dt = x.dtype
    xb = x.reshape(nb, tb, d)

    logits = (xb @ p["router"].astype(dt)).astype(jnp.float32)  # (nb,tb,E)
    weights, experts = jax.lax.top_k(logits, k)                 # (nb,tb,k)
    weights = jax.nn.softmax(weights, axis=-1)

    # per-block slot positions: running index of each (token, choice) within
    # its expert, local to the block. Sort-based - a (tbk, E) one-hot cumsum
    # materializes gigabytes at E=128 (observed ~4 GiB/device on arctic);
    # argsort + segment offsets is O(tbk log tbk) time and O(tbk) memory.
    flat_e = experts.reshape(nb, tb * k)

    def positions_one(e_idx):
        counts = jnp.zeros((E,), jnp.int32).at[e_idx].add(1)
        start = jnp.cumsum(counts) - counts          # exclusive prefix sum
        order = jnp.argsort(e_idx, stable=True)
        pos_sorted = jnp.arange(e_idx.shape[0], dtype=jnp.int32) \
            - start[e_idx[order]]
        return jnp.zeros_like(e_idx).at[order].set(pos_sorted)

    pos = jax.vmap(positions_one)(flat_e)
    keep = pos < C
    safe_e = jnp.where(keep, flat_e, 0)
    safe_p = jnp.where(keep, pos, C - 1)

    src = jnp.repeat(xb, k, axis=1) * keep[..., None].astype(dt)

    # batched scatter: block dim is a vmap batch dim => stays shard-local
    def scatter_one(e_idx, p_idx, upd):
        slots = jnp.zeros((E, C, d), dt)
        return slots.at[e_idx, p_idx].add(upd, mode="drop")

    slots = jax.vmap(scatter_one)(safe_e, safe_p, src)          # (nb,E,C,d)

    # grouped expert GEMMs (the real FLOPs); expert dim -> "model" axis
    if nb > 1:
        slots = _wsc(slots, "data", "model", None, None)
    g = jnp.einsum("becd,edf->becf", slots, p["w_gate"].astype(dt))
    u = jnp.einsum("becd,edf->becf", slots, p["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    out_slots = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dt))
    if nb > 1:
        out_slots = _wsc(out_slots, "data", "model", None, None)

    # batched gather back + router-weighted combine
    def gather_one(o, e_idx, p_idx):
        return o[e_idx, p_idx]

    gathered = jax.vmap(gather_one)(out_slots, safe_e, safe_p)  # (nb,tbk,d)
    gathered = gathered * keep[..., None].astype(dt)
    gathered = gathered * weights.reshape(nb, tb * k)[..., None].astype(dt)
    y = gathered.reshape(nb, tb, k, d).sum(axis=2)

    if "dense_mlp" in p:
        y = y + mlp(p["dense_mlp"], xb, cfg.mlp_act)
    return y.reshape(B, S, d)


def aux_load_balance_loss(p, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Switch-style load-balance auxiliary loss (mean over tokens)."""
    T = x.shape[0] * x.shape[1]
    logits = (x.reshape(T, -1) @ p["router"].astype(x.dtype)
              ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(logits, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
