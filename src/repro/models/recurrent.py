"""Recurrent sequence mixers: RG-LRU (Griffin/RecurrentGemma) and
xLSTM's mLSTM / sLSTM cells.

Training/prefill uses ``jax.lax.associative_scan`` where the recurrence is
affine (RG-LRU) and ``jax.lax.scan`` otherwise; decode is a single state
update - this is what makes ``long_500k`` O(1)-state for these archs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, split_keys

_SCAN_CHUNK = 128


def chunked_scan(f, init, xs, chunk: int = _SCAN_CHUNK):
    """Two-level scan with a checkpointed inner loop.

    A flat ``lax.scan`` over S steps saves the carry at every step for the
    backward pass - O(S x state) residuals, catastrophic for matrix-memory
    cells (mLSTM state is (B,H,hd,hd)). Chunking saves carries only at the
    S/chunk boundaries and recomputes inside a chunk (binomial
    checkpointing, one extra forward).
    """
    T = jax.tree.leaves(xs)[0].shape[0]
    if T % chunk or T <= chunk:
        return jax.lax.scan(f, init, xs)
    n = T // chunk
    xs_c = jax.tree.map(
        lambda x: x.reshape((n, chunk) + x.shape[1:]), xs)

    @jax.checkpoint
    def outer(carry, xc):
        return jax.lax.scan(f, carry, xc)

    carry, ys_c = jax.lax.scan(outer, init, xs_c)
    ys = jax.tree.map(
        lambda y: y.reshape((T,) + y.shape[2:]), ys_c)
    return carry, ys


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma recurrent block: conv1d + gated linear recurrence)
# ---------------------------------------------------------------------------

_CONV_K = 4
_C_GATE = 8.0


def init_rglru(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    ks = split_keys(key, ["in_x", "in_g", "conv", "a", "x_gate", "out",
                          "lam"])
    return {
        # block input projections (recurrent branch + gelu gate branch)
        "w_in_x": dense_init(ks["in_x"], (d, d)),
        "w_in_g": dense_init(ks["in_g"], (d, d)),
        "conv_w": dense_init(ks["conv"], (_CONV_K, d)) * 0.1,
        # RG-LRU gates
        "w_a": dense_init(ks["a"], (d, d)),
        "w_x": dense_init(ks["x_gate"], (d, d)),
        "b_a": jnp.zeros((d,), jnp.float32),
        "b_x": jnp.zeros((d,), jnp.float32),
        # recurrence decay parameter Lambda (softplus-parameterized)
        "lam": jnp.full((d,), 2.0, jnp.float32),
        "w_out": dense_init(ks["out"], (d, d)),
    }


def _rglru_gates(p, x):
    """a_t (decay) and gated input for the linear recurrence."""
    dt = x.dtype
    r = jax.nn.sigmoid((x @ p["w_a"].astype(dt)).astype(jnp.float32)
                       + p["b_a"])
    i = jax.nn.sigmoid((x @ p["w_x"].astype(dt)).astype(jnp.float32)
                       + p["b_x"])
    log_a = -_C_GATE * jax.nn.softplus(p["lam"]) * r       # (B,S,d) fp32
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * i * x.astype(jnp.float32)
    return a, b


def _conv1d_causal(w, x, state=None):
    """Depthwise causal conv, kernel K. x: (B,S,d). state: (B,K-1,d)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(K))
    new_state = xp[:, -(K - 1):]
    return out, new_state


def rglru_block(p, x, cfg: ModelConfig) -> jnp.ndarray:
    """Full-sequence recurrent block (train/prefill). x: (B,S,d)."""
    dt = x.dtype
    g = jax.nn.gelu(x @ p["w_in_g"].astype(dt))
    h = x @ p["w_in_x"].astype(dt)
    h, _ = _conv1d_causal(p["conv_w"], h)
    a, b = _rglru_gates(p, h)

    # h_t = a_t * h_{t-1} + b_t  - affine => associative scan over S
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a.swapaxes(0, 1),
                                               b.swapaxes(0, 1)))
    y = hs.swapaxes(0, 1).astype(dt)
    return (y * g) @ p["w_out"].astype(dt)


def init_rglru_state(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    return {"h": jnp.zeros((batch, d), jnp.float32),
            "conv": jnp.zeros((batch, _CONV_K - 1, d), dtype)}


def rglru_decode(p, x, cfg: ModelConfig, state) -> Tuple[jnp.ndarray, Dict]:
    """One-token step. x: (B,1,d)."""
    dt = x.dtype
    g = jax.nn.gelu(x @ p["w_in_g"].astype(dt))
    h = x @ p["w_in_x"].astype(dt)
    h, conv_state = _conv1d_causal(p["conv_w"], h, state["conv"])
    a, b = _rglru_gates(p, h)
    h_new = a[:, 0] * state["h"] + b[:, 0]
    y = h_new[:, None, :].astype(dt)
    out = (y * g) @ p["w_out"].astype(dt)
    return out, {"h": h_new, "conv": conv_state}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell)
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = split_keys(key, ["q", "k", "v", "i", "f", "o", "out"])
    return {
        "wq": dense_init(ks["q"], (d, H * hd)),
        "wk": dense_init(ks["k"], (d, H * hd)),
        "wv": dense_init(ks["v"], (d, H * hd)),
        "wi": dense_init(ks["i"], (d, H)),
        "wf": dense_init(ks["f"], (d, H)),
        "wo_gate": dense_init(ks["o"], (d, H * hd)),
        "w_out": dense_init(ks["out"], (H * hd, d)),
        "bf": jnp.full((H,), 3.0, jnp.float32),   # forget-open init
        "bi": jnp.zeros((H,), jnp.float32),
    }


def _mlstm_qkv(p, x, cfg):
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, H, hd) / jnp.sqrt(
        jnp.float32(hd)).astype(dt)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, H, hd)
    i_gate = ((x @ p["wi"].astype(dt)).astype(jnp.float32) + p["bi"])
    f_gate = ((x @ p["wf"].astype(dt)).astype(jnp.float32) + p["bf"])
    o_gate = jax.nn.sigmoid(x @ p["wo_gate"].astype(dt))
    return q, k, v, i_gate, f_gate, o_gate


def _mlstm_step(carry, inp):
    """Stabilized mLSTM recurrence (one time step, batched).

    carry: C (B,H,hd,hd), n (B,H,hd), m (B,H)
    inp:   q,k,v (B,H,hd); i,f (B,H)
    """
    C, n, m = carry
    q, k, v, i, f = inp
    m_new = jnp.maximum(f + m, i)
    fg = jnp.exp(f + m - m_new)[..., None]
    ig = jnp.exp(i - m_new)[..., None]
    C = fg[..., None] * C + ig[..., None] * (k[..., :, None] *
                                             v[..., None, :])
    n = fg * n + ig * k
    h_num = jnp.einsum("bhij,bhi->bhj", C, q.astype(C.dtype))
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n,
                                           q.astype(n.dtype))), 1.0)
    h = h_num / h_den[..., None]
    return (C, n, m_new), h


def mlstm_block(p, x, cfg: ModelConfig) -> jnp.ndarray:
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    dt = x.dtype
    q, k, v, i, f, o = _mlstm_qkv(p, x, cfg)
    q32, k32, v32 = (t.astype(jnp.float32).swapaxes(0, 1)
                     for t in (q, k, v))
    i32 = i.swapaxes(0, 1)
    f32 = jax.nn.log_sigmoid(f).swapaxes(0, 1)
    init = (jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32),
            jnp.full((B, H), -jnp.inf, jnp.float32))
    _, hs = chunked_scan(_mlstm_step, init, (q32, k32, v32, i32, f32))
    h = hs.swapaxes(0, 1).astype(dt).reshape(B, S, H * hd)
    return (h * o) @ p["w_out"].astype(dt)


def init_mlstm_state(cfg: ModelConfig, batch: int):
    H, hd = cfg.n_heads, cfg.hd
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.full((batch, H), -jnp.inf, jnp.float32)}


def mlstm_decode(p, x, cfg: ModelConfig, state) -> Tuple[jnp.ndarray, Dict]:
    B = x.shape[0]
    dt = x.dtype
    q, k, v, i, f, o = _mlstm_qkv(p, x, cfg)
    carry = (state["C"], state["n"], state["m"])
    inp = (q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
           v[:, 0].astype(jnp.float32), i[:, 0],
           jax.nn.log_sigmoid(f[:, 0]))
    (C, n, m), h = _mlstm_step(carry, inp)
    h = h.astype(dt).reshape(B, 1, -1)
    out = (h * o) @ p["w_out"].astype(dt)
    return out, {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory cell with exponential gating)
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    ks = split_keys(key, ["z", "i", "f", "o", "out"])
    return {
        "wz": dense_init(ks["z"], (d, d)),
        "wi": dense_init(ks["i"], (d, d)),
        "wf": dense_init(ks["f"], (d, d)),
        "wo_gate": dense_init(ks["o"], (d, d)),
        "w_out": dense_init(ks["out"], (d, d)),
        "bf": jnp.full((d,), 3.0, jnp.float32),
    }


def _slstm_step(carry, inp):
    """carry: c,n,m (B,d); inp: z,i,f,o (B,d) fp32 (pre-activation)."""
    c, n, m = carry
    z, i, f, o = inp
    logf = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(logf + m, i)
    fg = jnp.exp(logf + m - m_new)
    ig = jnp.exp(i - m_new)
    c = fg * c + ig * jnp.tanh(z)
    n = fg * n + ig
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
    return (c, n, m_new), h


def _slstm_pre(p, x):
    dt = x.dtype
    z = (x @ p["wz"].astype(dt)).astype(jnp.float32)
    i = (x @ p["wi"].astype(dt)).astype(jnp.float32)
    f = (x @ p["wf"].astype(dt)).astype(jnp.float32) + p["bf"]
    o = (x @ p["wo_gate"].astype(dt)).astype(jnp.float32)
    return z, i, f, o


def slstm_block(p, x, cfg: ModelConfig) -> jnp.ndarray:
    B, S, d = x.shape
    dt = x.dtype
    z, i, f, o = _slstm_pre(p, x)
    init = (jnp.zeros((B, d), jnp.float32), jnp.zeros((B, d), jnp.float32),
            jnp.full((B, d), -jnp.inf, jnp.float32))
    _, hs = chunked_scan(_slstm_step, init,
                         tuple(t.swapaxes(0, 1) for t in (z, i, f, o)))
    h = hs.swapaxes(0, 1).astype(dt)
    return h @ p["w_out"].astype(dt)


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -jnp.inf, jnp.float32)}


def slstm_decode(p, x, cfg: ModelConfig, state) -> Tuple[jnp.ndarray, Dict]:
    dt = x.dtype
    z, i, f, o = _slstm_pre(p, x)
    carry = (state["c"], state["n"], state["m"])
    (c, n, m), h = _slstm_step(carry, (z[:, 0], i[:, 0], f[:, 0], o[:, 0]))
    out = h[:, None, :].astype(dt) @ p["w_out"].astype(dt)
    return out, {"c": c, "n": n, "m": m}
