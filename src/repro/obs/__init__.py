"""``repro.obs`` - the fleet-wide observability layer (DESIGN.md SS.8).

Dependency-light structured tracing + metrics + post-mortem capture,
shared by every layer (router, scheduler, compiler, serve engines,
kernel dispatch). Three pieces:

* a span/event **tracer** (:mod:`repro.obs.trace`) exporting Chrome
  trace-event JSON loadable in Perfetto,
* a **metrics registry** (:mod:`repro.obs.metrics`) of counters, gauges
  and fixed-bucket histograms with ``snapshot()``/``as_dict()``,
* an SLO-breach **flight recorder** (:mod:`repro.obs.flight`): a ring
  buffer of the last N per-slice fleet frames, dumped as JSON when the
  running deadline-miss rate or p99 crosses a threshold.

Hot-path contract: instrumentation sites guard on :func:`enabled` - a
module-level boolean read - so with observability off (the default) the
added cost is one predicate per site and **no** allocation:

    from repro import obs

    if obs.enabled():
        t0 = obs.now_ns()
        ...
        obs.complete("sched.slice", t0, args={...}, tid=wid)

Rare events (a compiler LUT build, an autoscaler scale event) may write
through :func:`metrics` unconditionally; that is what keeps the fleet
CLI's lut-cache/autoscale reporting truthful even with tracing off.

Enable with :func:`enable` (optionally attaching a
:class:`~repro.obs.flight.FlightRecorder`), read back through
``repro.api.obs()``, export with :func:`export`. The state is
process-global on purpose: one fleet run = one timeline.
"""
from __future__ import annotations

from pathlib import Path
from typing import Any, Dict, Optional

from repro.obs.flight import FlightRecorder  # noqa: F401
from repro.obs.metrics import (TIME_US_BUCKETS,  # noqa: F401
                               WAIT_SLICE_BUCKETS, Histogram,
                               MetricsRegistry)
from repro.obs.trace import (NULL_SPAN, NullSpan, Span,  # noqa: F401
                             Tracer, now_ns, summarize_events)

__all__ = [
    "enabled", "enable", "disable", "reset",
    "tracer", "metrics", "flight_recorder", "set_flight_recorder",
    "span", "instant", "complete", "counter", "gauge", "observe",
    "export", "now_ns", "summarize_events",
    "Tracer", "MetricsRegistry", "FlightRecorder", "Histogram",
    "NULL_SPAN",
]

_enabled: bool = False
_tracer = Tracer()
_metrics = MetricsRegistry()
_flight: Optional[FlightRecorder] = None


# -- switches ----------------------------------------------------------------
def enabled() -> bool:
    """The one hot-path guard: True while tracing is on."""
    return _enabled


def enable(*, flight_recorder: Optional[FlightRecorder] = None) -> None:
    """Turn tracing on (idempotent); optionally attach a flight
    recorder in the same call."""
    global _enabled, _flight
    _enabled = True
    if flight_recorder is not None:
        _flight = flight_recorder


def disable() -> None:
    global _enabled
    _enabled = False


def reset() -> None:
    """Disable and drop all recorded state (tests; fresh CLI runs)."""
    global _enabled, _flight
    _enabled = False
    _flight = None
    _tracer.clear()
    _metrics.clear()


# -- accessors ----------------------------------------------------------------
def tracer() -> Tracer:
    return _tracer


def metrics() -> MetricsRegistry:
    return _metrics


def flight_recorder() -> Optional[FlightRecorder]:
    return _flight


def set_flight_recorder(rec: Optional[FlightRecorder]) -> None:
    global _flight
    _flight = rec


# -- recording shorthands -----------------------------------------------------
def span(name: str, cat: str = "repro", *, tid: Optional[int] = None,
         **attrs):
    """Context-manager span; the shared no-op singleton when disabled."""
    if not _enabled:
        return NULL_SPAN
    return _tracer.span(name, cat, tid=tid, **attrs)


def complete(name: str, t_start_ns: int, *, cat: str = "repro",
             args: Optional[Dict[str, Any]] = None,
             tid: Optional[int] = None) -> None:
    """Record a post-hoc span ending now (hot-path form; callers took
    ``t_start_ns = obs.now_ns()`` behind their own ``enabled()`` check)."""
    if not _enabled:
        return
    _tracer.complete(name, t_start_ns, now_ns(), cat=cat, args=args,
                     tid=tid)


def instant(name: str, *, cat: str = "repro",
            args: Optional[Dict[str, Any]] = None,
            tid: Optional[int] = None) -> None:
    if not _enabled:
        return
    _tracer.instant(name, cat=cat, args=args, tid=tid)


def counter(name: str, n: int = 1, **labels) -> None:
    if not _enabled:
        return
    _metrics.counter(name, n, **labels)


def gauge(name: str, value: float, **labels) -> None:
    if not _enabled:
        return
    _metrics.gauge(name, value, **labels)


def observe(name: str, value: float, *, buckets=TIME_US_BUCKETS,
            **labels) -> None:
    if not _enabled:
        return
    _metrics.observe(name, value, buckets=buckets, **labels)


# -- export -------------------------------------------------------------------
def export(trace_path=None, metrics_path=None) -> Dict[str, Path]:
    """Write ``trace.json`` (Chrome trace events) and/or ``metrics.json``
    (registry snapshot); returns the paths actually written."""
    import json

    out: Dict[str, Path] = {}
    if trace_path is not None:
        out["trace"] = _tracer.export(trace_path)
    if metrics_path is not None:
        p = Path(metrics_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(_metrics.as_dict(), indent=2))
        out["metrics"] = p
    return out
