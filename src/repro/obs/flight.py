"""SLO-breach flight recorder: a ring buffer of per-slice fleet state.

The fleet loop calls :meth:`FlightRecorder.record` once per slice with a
frame of per-engine state (queue depth, placement vector, LUT-cache
counters, admission decisions) and :meth:`FlightRecorder.check` with the
*running* SLO signals (deadline-miss rate, p99 latency). When a signal
crosses its threshold the recorder dumps the last ``capacity`` frames -
the post-mortem window leading up to the breach - as JSON, once per
breach episode (it re-arms only after the signal recovers below the
threshold, so a persistently-missing fleet produces one dump, not one
per slice).

The recorder is passive storage: it never reaches into schedulers or
routers itself, so what a frame contains is decided by the caller
(``repro.fleet.router.Fleet.run`` builds the canonical frame; see
DESIGN.md SS.8 for the schema).
"""
from __future__ import annotations

import collections
import json
from pathlib import Path
from typing import Any, Dict, List, Optional


class FlightRecorder:
    """Ring buffer of the last ``capacity`` slice frames + SLO triggers.

    ``miss_rate_threshold``/``p99_ms_threshold``: ``None`` disables that
    trigger. ``path=None`` keeps dumps in memory (``last_dump``), which
    is what tests use.
    """

    def __init__(self, capacity: int = 64, *,
                 miss_rate_threshold: Optional[float] = 0.5,
                 p99_ms_threshold: Optional[float] = None,
                 path=None) -> None:
        if capacity < 1:
            raise ValueError("flight recorder capacity must be >= 1")
        self.capacity = capacity
        self.miss_rate_threshold = miss_rate_threshold
        self.p99_ms_threshold = p99_ms_threshold
        self.path = Path(path) if path is not None else None
        self.frames: collections.deque = collections.deque(maxlen=capacity)
        self.n_dumps = 0
        self.last_dump: Optional[Dict[str, Any]] = None
        self._armed = True

    # -- per-slice protocol --------------------------------------------------
    def record(self, slice_idx: int, frame: Dict[str, Any]) -> None:
        """Append one slice frame (oldest rotates out past capacity)."""
        self.frames.append({"slice": slice_idx, **frame})

    def check(self, *, deadline_miss_rate: Optional[float] = None,
              p99_ms: Optional[float] = None,
              context: Optional[Dict[str, Any]] = None) -> Optional[Path]:
        """Evaluate the triggers; dump and return the path on a breach.

        Returns ``None`` when nothing fired (or the dump stayed
        in-memory because no ``path`` is set).
        """
        reasons = []
        if (self.miss_rate_threshold is not None
                and deadline_miss_rate is not None
                and deadline_miss_rate >= self.miss_rate_threshold):
            reasons.append(f"deadline_miss_rate {deadline_miss_rate:.3f} "
                           f">= {self.miss_rate_threshold:.3f}")
        if (self.p99_ms_threshold is not None and p99_ms is not None
                and p99_ms >= self.p99_ms_threshold):
            reasons.append(f"p99_ms {p99_ms:.3f} "
                           f">= {self.p99_ms_threshold:.3f}")
        if not reasons:
            self._armed = True          # recovered: re-arm for next breach
            return None
        if not self._armed:
            return None                 # still inside the same episode
        self._armed = False
        return self.dump("; ".join(reasons), context=context,
                         signals={"deadline_miss_rate": deadline_miss_rate,
                                  "p99_ms": p99_ms})

    # -- dumping ------------------------------------------------------------
    def dump(self, reason: str, *, context: Optional[Dict] = None,
             signals: Optional[Dict] = None) -> Optional[Path]:
        """Serialize the ring to JSON (post-mortem window)."""
        self.n_dumps += 1
        payload = {
            "reason": reason,
            "signals": signals or {},
            "context": context or {},
            "capacity": self.capacity,
            "n_frames": len(self.frames),
            "frames": list(self.frames),
        }
        self.last_dump = payload
        if self.path is None:
            return None
        # one file per dump so a second breach never clobbers the first
        out = self.path if self.n_dumps == 1 else self.path.with_name(
            f"{self.path.stem}.{self.n_dumps}{self.path.suffix}")
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(payload, indent=2, default=str))
        return out

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        return len(self.frames)

    def slices(self) -> List[int]:
        return [f["slice"] for f in self.frames]
