"""Counter/gauge/histogram registry with labeled instruments.

A :class:`MetricsRegistry` holds named instruments, each optionally
split by a set of string labels (``counter("fleet.admit", reason=
"queue_full")``). Instruments are created on first touch; histograms
use *fixed* bucket upper bounds fixed at creation (first ``observe``
wins, later calls reuse them), so snapshots from different engines
merge trivially. ``snapshot()``/``as_dict()`` return plain JSON-able
dicts - the ``metrics.json`` the fleet CLI writes is exactly one
``as_dict()``.

Thread-safe via one registry lock; the per-record work is a dict lookup
and an integer add, cheap enough to leave always-on for rare events
(compiler builds). Hot paths (per-slice, per-dispatch) additionally
guard on ``repro.obs.enabled()``.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

#: default histogram buckets for slice-denominated waits (upper bounds)
WAIT_SLICE_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0)
#: default buckets for wall-time micro-measurements, in microseconds
TIME_US_BUCKETS = (10.0, 50.0, 100.0, 500.0, 1e3, 5e3, 1e4, 1e5, 1e6)

Key = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: Dict[str, Any]) -> Key:
    return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))


def _fmt(key: Key) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram: ``counts[i]`` tallies observations with
    ``value <= buckets[i]``; the trailing slot is the +inf overflow."""

    __slots__ = ("buckets", "counts", "sum", "count", "min", "max")

    def __init__(self, buckets: Sequence[float]) -> None:
        if not buckets:
            raise ValueError("histogram needs at least one bucket bound")
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.counts = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def quantile(self, q: float) -> Optional[float]:
        """Bucket-resolution quantile (``q`` in [0, 100]): the upper
        bound of the bucket holding the nearest-rank observation, or the
        observed max for the +inf overflow slot. None when empty.
        Resolution is the bucket grid - good enough for the autoscaler /
        bench wait-distribution summaries it feeds."""
        if not self.count:
            return None
        rank = max(int(math.ceil(q / 100.0 * self.count)), 1)
        acc = 0
        for i, n in enumerate(self.counts):
            acc += n
            if acc >= rank:
                return (self.buckets[i] if i < len(self.buckets)
                        else self.max)
        return self.max

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold another histogram with the SAME bucket grid into this
        one (per-cell wait histograms -> one fleet-wide distribution)."""
        if other.buckets != self.buckets:
            raise ValueError(f"bucket grids differ: {self.buckets} vs "
                             f"{other.buckets}")
        for i, n in enumerate(other.counts):
            self.counts[i] += n
        self.sum += other.sum
        self.count += other.count
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def as_dict(self) -> Dict[str, Any]:
        return {"buckets": list(self.buckets),
                "counts": list(self.counts),
                "sum": self.sum, "count": self.count,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "mean": (self.sum / self.count) if self.count else None}


class MetricsRegistry:
    """Named, labeled counters/gauges/histograms behind one lock."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[Key, Counter] = {}
        self._gauges: Dict[Key, Gauge] = {}
        self._histograms: Dict[Key, Histogram] = {}

    # -- recording ----------------------------------------------------------
    def counter(self, name: str, n: int = 1, **labels) -> None:
        key = _key(name, labels)
        with self._lock:
            c = self._counters.get(key)
            if c is None:
                c = self._counters[key] = Counter()
            c.inc(n)

    def gauge(self, name: str, value: float, **labels) -> None:
        key = _key(name, labels)
        with self._lock:
            g = self._gauges.get(key)
            if g is None:
                g = self._gauges[key] = Gauge()
            g.set(value)

    def observe(self, name: str, value: float, *,
                buckets: Sequence[float] = TIME_US_BUCKETS,
                **labels) -> None:
        key = _key(name, labels)
        with self._lock:
            h = self._histograms.get(key)
            if h is None:
                h = self._histograms[key] = Histogram(buckets)
            h.observe(value)

    # -- reading ------------------------------------------------------------
    def value(self, name: str, default: int = 0, **labels) -> int:
        """Current counter value (0 for a never-touched counter)."""
        key = _key(name, labels)
        with self._lock:
            c = self._counters.get(key)
            return c.value if c is not None else default

    def gauge_value(self, name: str, default: float = 0.0,
                    **labels) -> float:
        key = _key(name, labels)
        with self._lock:
            g = self._gauges.get(key)
            return g.value if g is not None else default

    def histogram(self, name: str, **labels) -> Optional[Histogram]:
        with self._lock:
            return self._histograms.get(_key(name, labels))

    def as_dict(self) -> Dict[str, Any]:
        """JSON-able snapshot: flat ``name{label=value}`` keys per kind."""
        with self._lock:
            return {
                "counters": {_fmt(k): c.value
                             for k, c in sorted(self._counters.items())},
                "gauges": {_fmt(k): g.value
                           for k, g in sorted(self._gauges.items())},
                "histograms": {_fmt(k): h.as_dict()
                               for k, h in sorted(self._histograms.items())},
            }

    snapshot = as_dict

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    def render(self) -> List[str]:
        """Human-readable lines for the CLI text summary."""
        snap = self.as_dict()
        lines = []
        for name, v in snap["counters"].items():
            lines.append(f"counter   {name} = {v}")
        for name, v in snap["gauges"].items():
            lines.append(f"gauge     {name} = {v:g}")
        for name, h in snap["histograms"].items():
            mean = f"{h['mean']:.3g}" if h["count"] else "-"
            lines.append(f"histogram {name}: n={h['count']} mean={mean} "
                         f"min={h['min']} max={h['max']}")
        return lines
