"""Structured span/event tracer exporting Chrome trace-event JSON.

A :class:`Tracer` collects *complete* spans (``ph: "X"``) and *instant*
events (``ph: "i"``) on the process monotonic clock
(``time.perf_counter_ns``), thread-safe, and serializes them in the
Chrome trace-event format that Perfetto (ui.perfetto.dev) and
``chrome://tracing`` load directly:

    {"traceEvents": [{"name": ..., "cat": ..., "ph": "X",
                      "ts": <us>, "dur": <us>, "pid": ..., "tid": ...,
                      "args": {...}}, ...],
     "displayTimeUnit": "ms"}

``tid`` defaults to the OS thread id; fleet code passes logical track
ids (one per engine worker) plus :meth:`Tracer.name_track` metadata so
every engine renders as its own named row. Spans nest by ts/dur
containment per track, exactly Perfetto's slice semantics.

The hot-path contract lives one level up (``repro.obs``): call sites
guard on ``obs.enabled()`` so a disabled tracer costs one predicate,
not an allocation. The tracer itself never checks the global switch -
it is usable standalone in tests.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional


def now_ns() -> int:
    """Monotonic timestamp shared by every span in a process."""
    return time.perf_counter_ns()


class Span:
    """Context manager recording one complete ("X") event on exit.

    Attributes set through :meth:`set` (or the ``attrs`` mapping passed
    at construction) land in the event's ``args`` and show up in the
    Perfetto slice detail pane.
    """

    __slots__ = ("_tracer", "name", "cat", "tid", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 tid: Optional[int], attrs: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.tid = tid
        self.attrs = dict(attrs) if attrs else {}
        self._t0 = 0

    def set(self, key: str, value: Any) -> "Span":
        self.attrs[key] = value
        return self

    def __enter__(self) -> "Span":
        self._t0 = now_ns()
        return self

    def __exit__(self, *exc) -> None:
        self._tracer.complete(self.name, self._t0, now_ns(), cat=self.cat,
                              args=self.attrs, tid=self.tid)


class NullSpan:
    """Shared no-op stand-in returned while tracing is disabled."""

    __slots__ = ()

    def set(self, key: str, value: Any) -> "NullSpan":
        return self

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL_SPAN = NullSpan()


class Tracer:
    """Thread-safe collector of Chrome trace events (ts/dur in us)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._tracks: Dict[int, str] = {}
        self.pid = os.getpid()
        self.t0_ns = now_ns()

    # -- recording ----------------------------------------------------------
    def _ts_us(self, t_ns: int) -> float:
        return (t_ns - self.t0_ns) / 1e3

    def span(self, name: str, cat: str = "repro", *,
             tid: Optional[int] = None, **attrs) -> Span:
        """Open a complete-span context manager (records on ``__exit__``)."""
        return Span(self, name, cat, tid, attrs)

    def complete(self, name: str, t_start_ns: int, t_end_ns: int, *,
                 cat: str = "repro", args: Optional[Dict] = None,
                 tid: Optional[int] = None) -> None:
        """Record an already-timed span (post-hoc "X" event): hot paths
        take two clock reads and call this once, skipping the context
        manager allocation."""
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": self._ts_us(t_start_ns),
              "dur": max((t_end_ns - t_start_ns) / 1e3, 0.0),
              "pid": self.pid,
              "tid": threading.get_ident() if tid is None else tid,
              "args": args or {}}
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, *, cat: str = "repro",
                args: Optional[Dict] = None,
                tid: Optional[int] = None) -> None:
        """Record a zero-duration marker (``ph: "i"``, thread-scoped)."""
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": self._ts_us(now_ns()),
              "pid": self.pid,
              "tid": threading.get_ident() if tid is None else tid,
              "args": args or {}}
        with self._lock:
            self._events.append(ev)

    def name_track(self, tid: int, name: str) -> None:
        """Label a logical track (rendered as the row name in Perfetto)."""
        with self._lock:
            self._tracks[tid] = name

    # -- export -------------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        """Snapshot of the recorded events (copy; metadata not included)."""
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._tracks.clear()

    def to_chrome(self) -> Dict[str, Any]:
        """The full trace-event JSON object (with track-name metadata)."""
        with self._lock:
            meta = [{"name": "thread_name", "ph": "M", "pid": self.pid,
                     "tid": tid, "args": {"name": label}}
                    for tid, label in sorted(self._tracks.items())]
            return {"traceEvents": meta + list(self._events),
                    "displayTimeUnit": "ms"}

    def export(self, path) -> Path:
        """Write Perfetto-loadable JSON to ``path`` (parents created)."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_chrome()))
        return path


def summarize_events(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Aggregate trace events per span name: count, total/mean/max wall
    time. Shared by the obs CLI's text renderer and tests; accepts the
    ``traceEvents`` list of a loaded trace.json as-is."""
    agg: Dict[str, Dict[str, Any]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        a = agg.setdefault(ev["name"], {"name": ev["name"],
                                        "cat": ev.get("cat", ""),
                                        "count": 0, "total_us": 0.0,
                                        "max_us": 0.0})
        a["count"] += 1
        a["total_us"] += ev["dur"]
        a["max_us"] = max(a["max_us"], ev["dur"])
    rows = sorted(agg.values(), key=lambda r: -r["total_us"])
    for r in rows:
        r["mean_us"] = r["total_us"] / r["count"]
    return rows
