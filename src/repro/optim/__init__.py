from repro.optim.adamw import Optimizer, OptimizerConfig, make_optimizer
__all__ = ["Optimizer", "OptimizerConfig", "make_optimizer"]
