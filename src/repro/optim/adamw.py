"""AdamW with optional bf16 moments, plus Adafactor - pure pytree functions.

Optimizer state mirrors parameter sharding exactly (tree-structural), so
FSDP-sharded params give ZeRO-sharded optimizer states for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"          # adamw | adamw_bf16 | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jnp.ndarray],
                     Tuple[PyTree, PyTree]]


def cosine_lr(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    if cfg.kind == "adamw_mp":
        # ZeRO-1 mixed precision: compute params are bf16 (TP-only
        # sharding, gathered once per step); the f32 master copy and
        # moments live FSDP-sharded in the optimizer state. Kills the
        # per-microbatch-per-layer FSDP weight all-gathers that dominated
        # the train collective term (EXPERIMENTS.md SS.Perf iter 3).
        def init(params):
            return {
                "master": jax.tree.map(
                    lambda p: p.astype(jnp.float32), params),
                "m": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "v": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32),
            }

        def update(grads, state, params, _step_unused=None):
            step = state["step"] + 1
            lr = cosine_lr(cfg, step)
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
            b1, b2 = cfg.b1, cfg.b2
            bc1 = 1 - b1 ** step.astype(jnp.float32)
            bc2 = 1 - b2 ** step.astype(jnp.float32)

            def upd(p, g, w, m, v):
                g32 = g.astype(jnp.float32)
                m32 = b1 * m + (1 - b1) * g32
                v32 = b2 * v + (1 - b2) * g32 * g32
                delta = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
                if p.ndim >= 2:
                    delta = delta + cfg.weight_decay * w
                w_new = w - lr * delta
                return w_new.astype(p.dtype), w_new, m32, v32

            out = jax.tree.map(upd, params, grads, state["master"],
                               state["m"], state["v"])
            def is_t(t):
                return isinstance(t, tuple)
            new_p = jax.tree.map(lambda t: t[0], out, is_leaf=is_t)
            new_w = jax.tree.map(lambda t: t[1], out, is_leaf=is_t)
            new_m = jax.tree.map(lambda t: t[2], out, is_leaf=is_t)
            new_v = jax.tree.map(lambda t: t[3], out, is_leaf=is_t)
            return new_p, {"master": new_w, "m": new_m, "v": new_v,
                           "step": step}

        return Optimizer(init, update)

    if cfg.kind in ("adamw", "adamw_bf16"):
        mdt = jnp.float32 if cfg.kind == "adamw" else jnp.bfloat16

        def init(params):
            return {
                "m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
                "v": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
                "step": jnp.zeros((), jnp.int32),
            }

        def update(grads, state, params, _step_unused=None):
            step = state["step"] + 1
            lr = cosine_lr(cfg, step)
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
            b1, b2 = cfg.b1, cfg.b2
            bc1 = 1 - b1 ** step.astype(jnp.float32)
            bc2 = 1 - b2 ** step.astype(jnp.float32)

            def upd(p, g, m, v):
                g32 = g.astype(jnp.float32)
                m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
                v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
                mh = m32 / bc1
                vh = v32 / bc2
                delta = mh / (jnp.sqrt(vh) + cfg.eps)
                if p.ndim >= 2:   # decoupled weight decay on matrices only
                    delta = delta + cfg.weight_decay * p.astype(jnp.float32)
                new_p = p.astype(jnp.float32) - lr * delta
                return (new_p.astype(p.dtype), m32.astype(mdt),
                        v32.astype(mdt))

            out = jax.tree.map(upd, params, grads, state["m"], state["v"])
            new_p = jax.tree.map(lambda t: t[0], out,
                                 is_leaf=lambda t: isinstance(t, tuple))
            new_m = jax.tree.map(lambda t: t[1], out,
                                 is_leaf=lambda t: isinstance(t, tuple))
            new_v = jax.tree.map(lambda t: t[2], out,
                                 is_leaf=lambda t: isinstance(t, tuple))
            return new_p, {"m": new_m, "v": new_v, "step": step}

        return Optimizer(init, update)

    if cfg.kind == "adafactor":
        # factored second moment: vr (row) / vc (col) trees parallel to
        # params; 1-d params keep a full accumulator in vr (vc is a dummy).
        def init(params):
            vr = jax.tree.map(
                lambda p: jnp.zeros(p.shape[:-1] if p.ndim >= 2 else p.shape,
                                    jnp.float32), params)
            vc = jax.tree.map(
                lambda p: jnp.zeros(p.shape[:-2] + p.shape[-1:]
                                    if p.ndim >= 2 else (1,), jnp.float32),
                params)
            return {"vr": vr, "vc": vc, "step": jnp.zeros((), jnp.int32)}

        def update(grads, state, params, _step_unused=None):
            step = state["step"] + 1
            lr = cosine_lr(cfg, step)
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
            decay = 1.0 - step.astype(jnp.float32) ** -0.8

            def upd(p, g, vr, vc):
                g32 = g.astype(jnp.float32)
                g2 = g32 * g32 + 1e-30
                if p.ndim >= 2:
                    vr_n = decay * vr + (1 - decay) * g2.mean(axis=-1)
                    vc_n = decay * vc + (1 - decay) * g2.mean(axis=-2)
                    denom = vr_n.mean(axis=-1, keepdims=True)
                    vhat = (vr_n[..., None] * vc_n[..., None, :]
                            / jnp.maximum(denom[..., None], 1e-30))
                    upd_ = g32 / jnp.sqrt(vhat + cfg.eps)
                    upd_ = upd_ + cfg.weight_decay * p.astype(jnp.float32)
                else:
                    vr_n = decay * vr + (1 - decay) * g2
                    vc_n = vc
                    upd_ = g32 / jnp.sqrt(vr_n + cfg.eps)
                new_p = p.astype(jnp.float32) - lr * upd_
                return new_p.astype(p.dtype), vr_n, vc_n

            out = jax.tree.map(upd, params, grads, state["vr"], state["vc"])
            def is_pair(t):
                return isinstance(t, tuple)
            new_p = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
            new_vr = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
            new_vc = jax.tree.map(lambda t: t[2], out, is_leaf=is_pair)
            return new_p, {"vr": new_vr, "vc": new_vc, "step": step}

        return Optimizer(init, update)

    raise ValueError(cfg.kind)
