"""INT8 gradient compression with error feedback.

For cross-pod data parallelism the gradient all-reduce crosses the slow DCI
links; int8 quantization cuts those bytes 4x (vs f32 accumulators). Error
feedback (Seide et al. / EF-SGD) keeps the residual locally and re-injects
it next step, making the compression unbiased in the long run - the
property test in tests/test_substrate.py checks the accumulated error stays
bounded and training still converges on the tiny example.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def compress_leaf(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g32 = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_leaf(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error_state(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads: PyTree, error: PyTree
                           ) -> Tuple[PyTree, PyTree]:
    """Returns (decompressed grads as would survive the wire, new error).

    The caller all-reduces the int8 payload; here we model the full
    quantize -> transmit -> dequantize path so the train loop can use it
    uniformly on any topology.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress_leaf(corrected)
        deq = decompress_leaf(q, s)
        return deq.astype(g.dtype), corrected - deq

    pairs = jax.tree.map(one, grads, error)
    def is_pair(t):
        return isinstance(t, tuple)
    out_g = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    out_e = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return out_g, out_e
