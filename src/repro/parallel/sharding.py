"""Sharding rules: map every param/state/batch tensor to a PartitionSpec.

Convention (DESIGN.md SS.6):
  * batch dims            -> ("pod", "data") (whichever divide)
  * matmul "wide" dims    -> "model" (tensor parallelism)
  * matmul "narrow" dims  -> "data"  (FSDP-style parameter sharding)
  * MoE expert dim        -> "model" (expert parallelism)
  * scanned-stack leading dim, biases, norms -> replicated

Rules are divisibility-guarded: a dim that does not divide its axis is
replicated instead, so the same rules serve 16x16, 2x16x16 and tiny test
meshes.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        n = int(np.prod([_axis_size(mesh, a) for a in axis]))
    else:
        n = _axis_size(mesh, axis)
    return dim % n == 0 and n > 1


def _guard(shape, mesh: Mesh, *axes) -> P:
    """PartitionSpec with divisibility fallback to replication per dim."""
    spec = []
    for dim, ax in zip(shape, axes):
        spec.append(ax if _fits(dim, mesh, ax) else None)
    return P(*spec)


# ---------------------------------------------------------------------------
# parameter rules (keyed on param path names from repro.models.lm)
# ---------------------------------------------------------------------------

_MODEL_OUT = ("wq", "wk", "wv", "w_gate", "w_up", "w_in_x", "w_in_g",
              "w_a", "w_x", "wz", "wi", "wf", "wo_gate", "lm_head")
_MODEL_IN = ("wo", "w_down", "w_out")


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               mesh: Mesh, scanned: bool, inference: bool = False) -> P:
    """PartitionSpec for one parameter identified by its tree path.

    ``inference=True`` drops the FSDP "data" factor (params replicated
    across data ranks): without grads/optimizer the data-sharding only buys
    capacity, and its per-matmul weight all-gathers dominated the decode
    collective term (3.1 GiB/step measured on qwen decode)."""
    name = path[-1]
    lead: Tuple = (None,) if scanned else ()
    body = shape[1:] if scanned else shape

    def out(*axes) -> P:
        if inference:
            axes = tuple(None if a == "data" else a for a in axes)
        return P(*lead, *_guard(body, mesh, *axes).__iter__())

    if len(body) <= 1:
        return out(None)
    if name == "embed":
        # vocab REPLICATED: a vocab-sharded table turns the token gather
        # into a full-table all-gather + involuntary remat (observed);
        # d_model-sharded rows keep the gather local.
        return out(None, "model")
    if name == "router":
        return out("data", None)
    if name in ("w_gate", "w_up", "w_down") and len(body) == 3:
        # MoE experts: (E, d, f) / (E, f, d) - expert-parallel + FSDP
        if name == "w_down":
            return out("model", None, "data")
        return out("model", "data", None)
    if name == "conv_w":
        return out(None, "model")
    if name in _MODEL_OUT:
        return out("data", "model")
    if name in _MODEL_IN:
        return out("model", "data")
    return out(*([None] * len(body)))


def params_shardings(params_abstract: PyTree, mesh: Mesh,
                     inference: bool = False) -> PyTree:
    """NamedShardings for a (possibly abstract) param pytree."""
    def visit(path, leaf):
        names = tuple(getattr(p, "key", getattr(p, "name", str(p)))
                      for p in path)
        scanned = "scan" in names
        spec = param_spec(names, leaf.shape, mesh, scanned, inference)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, params_abstract)


def inference_fits_tp_only(params_abstract: PyTree, mesh: Mesh,
                           budget_bytes: float = 8 * 2 ** 30) -> bool:
    """True if TP-only residency (replicated over data) fits per device."""
    total = sum(x.size * 2 for x in jax.tree_util.tree_leaves(
        params_abstract))
    return total / _axis_size(mesh, "model") <= budget_bytes


# ---------------------------------------------------------------------------
# batch / activation / decode-state rules
# ---------------------------------------------------------------------------


def batch_spec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Shard dim0 (batch) over pod x data when divisible."""
    dp = dp_axes(mesh)
    if dp and _fits(shape[0], mesh, dp):
        return P(dp, *([None] * (len(shape) - 1)))
    # try data only
    if "data" in mesh.shape and _fits(shape[0], mesh, "data"):
        return P("data", *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def batch_shardings(batch_abstract: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda x: NamedSharding(mesh, batch_spec(x.shape, mesh)),
        batch_abstract)


def decode_state_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
                      mesh: Mesh, scanned: bool) -> P:
    """KV caches: (B, S, KV, hd) -> (dp, None, None, model-on-hd);
    recurrent states: batch + widest dim on model."""
    name = path[-1]
    lead: Tuple = (None,) if scanned else ()
    body = shape[1:] if scanned else shape
    dp = dp_axes(mesh)
    b_ax = dp if _fits(body[0], mesh, dp) else (
        "data" if _fits(body[0], mesh, "data") else None)
    if name in ("k", "v") and len(body) == 4:
        # sequence-sharded KV: decode attention becomes distributed-softmax
        # (local logits + tiny stat/PV reductions). hd-sharding instead
        # makes SPMD all-gather the whole cache every layer (measured
        # 3.1 GiB/step on qwen decode - EXPERIMENTS.md SS.Perf iter 1).
        if _fits(body[1], mesh, "model"):
            return P(*lead, b_ax, "model", None, None)
        return P(*lead, b_ax, None, None,
                 "model" if _fits(body[3], mesh, "model") else None)
    if name == "C" and len(body) == 4:          # mLSTM (B,H,hd,hd)
        return P(*lead, b_ax, None,
                 "model" if _fits(body[2], mesh, "model") else None, None)
    if len(body) >= 2:
        last = "model" if _fits(body[-1], mesh, "model") else None
        mid = [None] * (len(body) - 2)
        return P(*lead, b_ax, *mid, last)
    return P(*lead, b_ax)


def decode_state_shardings(state_abstract: PyTree, mesh: Mesh) -> PyTree:
    def visit(path, leaf):
        names = tuple(getattr(p, "key", getattr(p, "name", str(p)))
                      for p in path)
        scanned = "scan" in names
        spec = decode_state_spec(names, leaf.shape, mesh, scanned)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, state_abstract)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# optimizer-state rules (mirror parameter shardings)
# ---------------------------------------------------------------------------


def params_shardings_like(opt_abstract: PyTree, params_abstract: PyTree,
                          pshard: PyTree, mesh: Mesh) -> PyTree:
    """Shardings for optimizer state trees.

    adam m/v mirror params exactly; adafactor vr drops the last dim of the
    param spec, vc drops the second-to-last; scalars are replicated.
    """
    def drop_last(s: NamedSharding) -> NamedSharding:
        spec = tuple(s.spec)
        return NamedSharding(mesh, P(*spec[:-1])) if spec else s

    def drop_second_last(s: NamedSharding) -> NamedSharding:
        spec = tuple(s.spec)
        if len(spec) >= 2:
            return NamedSharding(mesh, P(*spec[:-2], spec[-1]))
        return NamedSharding(mesh, P())

    out = {}
    for key, sub in opt_abstract.items():
        if key in ("m", "v", "master"):
            # ZeRO-style: moments/master are FSDP-sharded even when the
            # compute params are TP-only (pshard may carry inference=True)
            out[key] = jax.tree_util.tree_map_with_path(
                lambda path, leaf: NamedSharding(mesh, param_spec(
                    tuple(getattr(p, "key", getattr(p, "name", str(p)))
                          for p in path),
                    leaf.shape, mesh,
                    "scan" in tuple(str(getattr(p, "key", p))
                                    for p in path))),
                sub)
        elif key == "vr":
            out[key] = jax.tree.map(
                lambda p, s: drop_last(s) if p.ndim >= 2
                else NamedSharding(mesh, P(*tuple(s.spec))),
                params_abstract, pshard)
        elif key == "vc":
            out[key] = jax.tree.map(
                lambda p, s: drop_second_last(s) if p.ndim >= 2
                else replicated(mesh),
                params_abstract, pshard)
        else:
            out[key] = jax.tree.map(lambda _: replicated(mesh), sub)
    return out
