"""Symmetric per-channel INT8 quantization - the "MRAM tier" weight format
(DESIGN.md SS.3). Used by the HH-PIM serving runtime and the pim_mac kernel.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_per_channel(w: jnp.ndarray, axis: int = 0
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """w (float) -> (int8 values, float32 scales along `axis`-complement).

    Symmetric: w ~= q * scale. Scales are per output column for a (d_in,
    d_out) matrix with axis=0 (reduce over d_in).
    """
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), jnp.squeeze(scale, axis=axis)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, axis: int = 0,
               dtype=jnp.float32) -> jnp.ndarray:
    s = jnp.expand_dims(scale, axis)
    return (q.astype(jnp.float32) * s).astype(dtype)


def quantize_activations(x: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row (token) symmetric int8 activation quantization."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale[..., 0]


def fake_quant(w: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Straight-through QAT helper: value of quant-dequant, gradient of
    identity."""
    q, s = quantize_per_channel(jax.lax.stop_gradient(w), axis)
    deq = dequantize(q, s, axis, w.dtype)
    return w + jax.lax.stop_gradient(deq - w)
