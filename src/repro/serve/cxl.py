"""CXL-tier re-parameterization of Eq. (1) - the edge-to-cloud memory
tiering substrate (ROADMAP item; after Oliveira et al., "Accelerating
NN Inference with Processing-in-DRAM", PAPERS.md).

Oliveira et al. argue edge-to-cloud PIM viability hinges on cheap
re-optimization as workloads move across memory tiers; this substrate
instantiates exactly that tier pair for the placement engine:

- **Clusters**: an HP pool of performance nodes at full clock and an LP
  pool of efficiency nodes at ``lp_clock`` of it (voltage tracking
  frequency, the same DVFS voltage curve as the GPU pools, owned by
  the registered :data:`TECH` model - see :mod:`repro.core.techmodel`).
- **Memory kinds as residency tiers**: node-local DDR residency is the
  "SRAM" tier (the node's DRAM channels stay active while holding
  weights: refresh + PHY, i.e. volatile), CXL-attached residency is the
  "MRAM" tier (far memory behind the CXL link; reads pay the link's
  latency/SerDes-energy premium, but the expander can drop to deep
  power-down when the pool idles, i.e. non-volatile). Weights are INT8
  in both tiers - unlike the bf16/int8 pools, the trade is purely
  locality vs standby power, the Oliveira et al. DRAM-tiering trade.
  ``rho`` is the batch reuse of one weight fetch.

Eq. (1) is isomorphic - Algorithms 1/2 only see per-space ``(t_i,
e_i)`` - so ``cxl_arch()`` builds a :class:`~repro.core.spaces.PIMArch`
from the constants below and the whole placement stack runs unchanged.
Constants are documented DDR5/CXL-1.1-class estimates per node.

``cxl_arch3()`` deepens the hierarchy to THREE pools (HBM accelerator
nodes / node-DDR standard nodes / a DVFS-scaled far pool behind the
CXL link), each anchoring one residency tier - the first 3-cluster
arch, solved through the K-pool min-plus combine
(:mod:`repro.core.multipool`, DESIGN.md SS.7).

This module is import-light on purpose (no jax): the substrate registry
builds archs from it without pulling in the serving runtime.
"""
from __future__ import annotations

import dataclasses

from repro.core import spaces as sp
from repro.core.techmodel import CXL_NODE_10NM

#: registered per-tech-node physics of the CXL node pools (DESIGN.md
#: SS.10). The voltage curve matches the GPU pools' (this module
#: historically imported ``repro.serve.gpu.dvfs_energy_scale``), so
#: existing LUTs are byte-identical; only the DVFS operating bounds
#: differ (node fabrics hold a higher frequency floor).
TECH = CXL_NODE_10NM


def dvfs_energy_scale(clock: float) -> float:
    """Dynamic-energy scale at frequency scale ``clock`` - the
    registered :data:`TECH` model's ``V^2`` curve."""
    return TECH.energy_scale(clock)

# -- per-node constants (documented estimates) ------------------------------
PEAK_FLOPS = 4e12            # INT8 MAC throughput of one node's engine
DDR_BW = 64e9                # B/s, local DDR5 channels of one node
CXL_BW = 24e9                # B/s, the node's CXL.mem link share
DDR_PJ_PER_BYTE = 12.0       # device + controller access energy
CXL_PJ_PER_BYTE = 21.0       # DDR on the expander + link SerDes both ways
MAC_PJ = 2.0                 # INT8 MAC incl. operand routing
# Incremental standby power of keeping a residency tier live (same
# dynamic-dominated regime as the other pool substrates): local DDR must
# keep refresh + channel PHY up while holding weights; the CXL expander
# supports deep power-down with retention when its pool idles.
DDR_IDLE_W = 9.0             # node DDR channels active, holding weights
CXL_SLEEP_W = 1.5            # expander in retention power-down
DDR_GB_PER_NODE = 32         # local capacity slice
CXL_GB_PER_NODE = 128        # far-memory capacity slice

LP_CLOCK = 0.5               # default clock scale of the efficiency pool

# -- three-tier (cxl-tier-3) constants --------------------------------------
# An accelerator-node pool whose weights sit in on-package HBM: the
# fastest, most access-efficient tier, but the stack's PHY + controller
# stay powered while it holds data (volatile, like local DDR).
HBM_BW = 819e9               # B/s per node (HBM2e-class stack share)
HBM_PJ_PER_BYTE = 5.0        # on-package access energy
HBM_GB_PER_NODE = 16         # HBM capacity slice per node
# Three-tier statics model only the INCREMENTAL cost of pinning a
# residency tier on - the refresh + PHY share attributable to the held
# weight shard (a model is a sliver of a 16-128 GB tier), not
# whole-channel idle draw. Same rationale as repro.serve.gpu.IDLE_W:
# the placement trade must stay dynamic-dominated for the paper's
# dynamic-only DP to remain near-optimal - the multipool
# dp-vs-closed-form CI gate holds at <= ~1% deviation with identical
# deadline behaviour in this regime (it degrades to ~10% with
# whole-channel statics, where the statics-aware closed-form argmin
# departs from the DP's in the near-tie mid-constraint region).
# (DDR_IDLE_W above stays as the 2-pool cxl-tier's whole-channel
# constant for LUT compatibility.)
HBM_PIN_W = 0.2              # stack PHY + refresh share of the shard
DDR_PIN_W = 0.15             # channel refresh + PHY share while holding
CXL_RETENTION_W = 0.05       # expander retention power-down


def _mem(kind: str, energy: float) -> sp.MemorySpec:
    """One residency tier on one node: ``sram`` = local DDR (volatile),
    ``mram`` = CXL-attached (non-volatile analogue). INT8 weights, one
    byte per use in both tiers; link bandwidth does not scale with the
    node's DVFS point, only node-side compute does."""
    bw = DDR_BW if kind == "sram" else CXL_BW
    pj_byte = DDR_PJ_PER_BYTE if kind == "sram" else CXL_PJ_PER_BYTE
    cap_gb = DDR_GB_PER_NODE if kind == "sram" else CXL_GB_PER_NODE
    static_w = DDR_IDLE_W if kind == "sram" else CXL_SLEEP_W
    read_ns = 1.0 / bw * 1e9
    return sp.MemorySpec(
        kind, read_ns=read_ns, write_ns=4 * read_ns,
        read_mw=pj_byte / read_ns, write_mw=pj_byte / (2 * read_ns),
        static_mw=static_w * 1e3 * energy,       # W -> mW
        volatile=(kind == "sram"),
        capacity_bytes=cap_gb * 2 ** 30)


def _pe(clock: float, energy: float) -> sp.PESpec:
    op_ns = 1.0 / PEAK_FLOPS / clock * 1e9       # one INT8 MAC
    return sp.PESpec(op_ns=op_ns, dyn_mw=MAC_PJ * energy / op_ns,
                     static_mw=0.0)


def cxl_arch(n_hp_nodes: int = 4, n_lp_nodes: int = 4, *,
             lp_clock: float = LP_CLOCK) -> sp.PIMArch:
    """HP/LP node pools x {local DDR, CXL-attached} residency as a
    PIMArch."""
    lp_energy = dvfs_energy_scale(lp_clock)
    hp = sp.ClusterSpec("hp", _pe(1.0, 1.0), n_hp_nodes, ())
    lp = sp.ClusterSpec("lp", _pe(lp_clock, lp_energy), n_lp_nodes, ())

    def spaces_for(c: sp.ClusterSpec, energy: float) -> tuple:
        mram = _mem("mram", energy)
        sram = _mem("sram", energy)
        return (
            sp.StorageSpace(f"{c.name}_mram", c.name, mram, sram, c.pe,
                            c.n_modules),
            sp.StorageSpace(f"{c.name}_sram", c.name, sram, sram, c.pe,
                            c.n_modules),
        )

    hp = dataclasses.replace(hp, spaces=spaces_for(hp, 1.0))
    lp = dataclasses.replace(lp, spaces=spaces_for(lp, lp_energy))
    return sp.PIMArch("cxl_tier", (hp, lp))


def _tier_mem(kind: str, bw: float, pj_byte: float, cap_gb: int,
              static_w: float, energy: float) -> sp.MemorySpec:
    """One residency tier of the three-tier hierarchy. ``kind`` carries
    the volatility semantics the placement engine keys on: ``sram`` =
    stays powered while holding (HBM stack / DDR refresh+PHY), ``mram``
    = retention power-down when the pool idles (CXL expander)."""
    read_ns = 1.0 / bw * 1e9
    return sp.MemorySpec(
        kind, read_ns=read_ns, write_ns=4 * read_ns,
        read_mw=pj_byte / read_ns, write_mw=pj_byte / (2 * read_ns),
        static_mw=static_w * 1e3 * energy,       # W -> mW
        volatile=(kind == "sram"),
        capacity_bytes=cap_gb * 2 ** 30)


def cxl_arch3(n_hbm_nodes: int = 2, n_ddr_nodes: int = 4,
              n_cxl_nodes: int = 4, *,
              lp_clock: float = LP_CLOCK) -> sp.PIMArch:
    """Three-tier memory hierarchy as THREE compute pools: an HBM pool
    (accelerator nodes, on-package residency), a node-DDR pool (standard
    nodes, local-DDR residency) and a DVFS-scaled far pool behind the
    CXL link (expander residency, retention power-down when idle).

    Each pool anchors one residency tier, so placement across the
    hierarchy is a genuine 3-cluster split - the first substrate to
    exercise the K-pool min-plus combine
    (:mod:`repro.core.multipool`). Every pool reads activations from a
    node-local DDR I/O buffer (the cross-tier analogue of the SRAM I/O
    role in the edge archs)."""
    far_energy = dvfs_energy_scale(lp_clock)

    def pool(name: str, n: int, clock: float, energy: float,
             mem: sp.MemorySpec) -> sp.ClusterSpec:
        c = sp.ClusterSpec(name, _pe(clock, energy), n, ())
        io = _tier_mem("sram", DDR_BW, DDR_PJ_PER_BYTE, DDR_GB_PER_NODE,
                       DDR_PIN_W, energy)      # node-local activation path
        space = sp.StorageSpace(f"{name}_{mem.kind}", name, mem, io,
                                c.pe, c.n_modules)
        return dataclasses.replace(c, spaces=(space,))

    hbm = pool("hbm", n_hbm_nodes, 1.0, 1.0,
               _tier_mem("sram", HBM_BW, HBM_PJ_PER_BYTE,
                         HBM_GB_PER_NODE, HBM_PIN_W, 1.0))
    ddr = pool("ddr", n_ddr_nodes, 1.0, 1.0,
               _tier_mem("sram", DDR_BW, DDR_PJ_PER_BYTE,
                         DDR_GB_PER_NODE, DDR_PIN_W, 1.0))
    cxl = pool("cxl", n_cxl_nodes, lp_clock, far_energy,
               _tier_mem("mram", CXL_BW, CXL_PJ_PER_BYTE,
                         CXL_GB_PER_NODE, CXL_RETENTION_W, far_energy))
    return sp.PIMArch("cxl_tier3", (hbm, ddr, cxl))
