"""Batched decode engine with slot-based continuous batching.

Requests occupy fixed batch slots; finished slots are refilled from the
queue each step (decode-time continuous batching). The KV/recurrent state
is allocated once at ``max_len`` and reused across requests per slot.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.common import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 128):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * max_batch
        self._slot_pos = np.zeros(max_batch, np.int32)
        self._state = lm.init_decode_state(cfg, max_batch, max_len)
        self._toks = jnp.zeros((max_batch,), jnp.int32)
        self._step_fn = jax.jit(
            lambda st, tk, pos: lm.decode_step(params, cfg, st, tk, pos))
        self._pos = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fill_slots(self) -> None:
        for i, s in enumerate(self.slots):
            if (s is None or s.done) and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # feed the prompt one token at a time into this slot
                toks = np.array(self._toks)
                for t in req.prompt[:-1]:
                    toks[i] = t
                    self._toks = jnp.asarray(toks)
                    _, self._state = self._step_fn(self._state, self._toks,
                                                   jnp.int32(self._pos))
                    self._pos += 1
                toks[i] = req.prompt[-1]
                self._toks = jnp.asarray(toks)

    def step(self) -> Dict[int, int]:
        """Decode one token for every active slot; returns {rid: token}."""
        self._fill_slots()
        if all(s is None or s.done for s in self.slots):
            return {}
        logits, self._state = self._step_fn(self._state, self._toks,
                                            jnp.int32(self._pos))
        self._pos += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        out = {}
        toks = np.asarray(self._toks).copy()
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            out[req.rid] = tok
            toks[i] = tok
            if len(req.out) >= req.max_new_tokens:
                req.done = True
        self._toks = jnp.asarray(toks)
        return out

    def run_until_done(self, max_steps: int = 1000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(s is None or s.done
                                      for s in self.slots):
                break
            self.step()
        return [s for s in self.slots if s is not None]
