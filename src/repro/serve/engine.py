"""Batched decode engine with slot-based continuous batching.

Requests occupy fixed batch slots; finished slots are refilled from the
queue each step (decode-time continuous batching). The KV/recurrent state
is allocated once at ``max_len`` and reused across requests per slot.

Slot refill uses a *batched prefill*: the prompts of every newly seated
request are pushed through one jitted ``lax.scan`` per distinct prompt
length (O(1) engine steps per refill group, instead of one full-batch
decode step per prompt token) and the resulting per-request state is
scattered into the engine's batched decode state at the refilled slot
rows. Each slot carries its own decode position (``attention_decode``
accepts per-row positions), so a refilled request's cache and RoPE phases
are coherent regardless of how far other slots have decoded. Grouping by
exact length means no pad tokens ever enter the state - required for
recurrent blocks and local-attention ring buffers, where padding is not
maskable after the fact. Batch shapes are bucketed to powers of two,
bounding XLA compiles at O(log max_batch * distinct prompt lengths).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import lm
from repro.models.common import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # step-level latency accounting (wall-clock seconds, perf_counter)
    t_submit: Optional[float] = None
    t_start: Optional[float] = None       # seated in a slot (prefill begins)
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_submit is None or self.t_done is None:
            return None
        return self.t_done - self.t_submit

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.t_submit is None or self.t_start is None:
            return None
        return self.t_start - self.t_submit


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 128):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.completed: List[Request] = []
        self._state = lm.init_decode_state(cfg, max_batch, max_len)
        self._toks = jnp.zeros((max_batch,), jnp.int32)
        # per-slot absolute decode position (requests start at different
        # times; attention_decode takes a position vector)
        self._slot_pos = np.zeros(max_batch, np.int32)
        self._step_fn = jax.jit(
            lambda st, tk, pos: lm.decode_step(params, cfg, st, tk, pos))
        self._prefill_fns: Dict[Tuple[int, int], callable] = {}
        self.step_times_s: List[float] = []

    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    # -- batched prefill ---------------------------------------------------
    @staticmethod
    def _bucket(n: int) -> int:
        return 1 << (n - 1).bit_length() if n > 1 else 1

    def _prefill_fn(self, n: int, L: int):
        """Jitted prompt prefill for ``n`` fresh requests of exact length
        ``L``: builds their decode state in one call (scan over tokens).
        ``n`` arrives bucketed to a power of two, so the compile cache
        stays O(log max_batch * distinct prompt lengths)."""
        key = (n, L)
        if key not in self._prefill_fns:
            cfg, params, max_len = self.cfg, self.params, self.max_len

            def fn(prompts):              # (n, L) int32
                state = lm.init_decode_state(cfg, n, max_len)

                def body(carry, tok):
                    st, pos = carry
                    _, st = lm.decode_step(params, cfg, st, tok, pos)
                    return (st, pos + 1), None

                (state, _), _ = jax.lax.scan(
                    body, (state, jnp.int32(0)),
                    jnp.swapaxes(prompts, 0, 1)[:-1])
                return state

            self._prefill_fns[key] = jax.jit(fn)
        return self._prefill_fns[key]

    def _scatter_state(self, slot_idx: List[int], new_state) -> None:
        """Write per-request decode state rows into the batched engine state
        at ``slot_idx`` (extra bucket-padding rows are dropped). Scanned
        stacks carry a leading group axis, so their batch axis is 1;
        unscanned ("tail") leaves batch at axis 0."""
        idx = jnp.asarray(slot_idx, jnp.int32)
        n = len(slot_idx)

        def put(path, big, small):
            axis = 1 if any(getattr(k, "key", None) == "scan"
                            for k in path) else 0
            sel = (slice(None),) * axis + (idx,)
            rows = (slice(None),) * axis + (slice(0, n),)
            return big.at[sel].set(small[rows].astype(big.dtype))

        layers = jax.tree_util.tree_map_with_path(
            put, self._state["layers"], new_state["layers"])
        self._state = dict(self._state)
        self._state["layers"] = layers

    def _fill_slots(self) -> None:
        refills: List[Tuple[int, Request]] = []
        for i, s in enumerate(self.slots):
            if (s is None or s.done) and self.queue:
                req = self.queue.pop(0)
                req.t_start = time.perf_counter()
                self.slots[i] = req
                refills.append((i, req))
        if not refills:
            return
        # one batched prefill per distinct prompt length: no pad tokens
        # ever reach the state, so recurrent layers and local-attention
        # ring buffers see exactly the prompt prefix (padding could only
        # be masked out of full-attention KV, not of carried state)
        by_len: Dict[int, List[Tuple[int, Request]]] = {}
        for i, r in refills:
            by_len.setdefault(len(r.prompt), []).append((i, r))
        toks = np.array(self._toks)
        for L, group in by_len.items():
            n = self._bucket(len(group))
            mat = np.zeros((n, L), np.int32)
            for j, (_, r) in enumerate(group):
                mat[j] = r.prompt
            with obs.span("engine.prefill", "engine", n_requests=len(group),
                          bucket=n, prompt_len=L):
                new_state = self._prefill_fn(n, L)(jnp.asarray(mat))
                self._scatter_state([i for i, _ in group], new_state)
            for i, r in group:
                toks[i] = r.prompt[-1]
                # prompt prefix state covers positions 0..L-2; the last
                # prompt token is decoded next step at its position L-1
                self._slot_pos[i] = L - 1
        self._toks = jnp.asarray(toks)

    def step(self) -> Dict[int, int]:
        """Decode one token for every active slot; returns {rid: token}."""
        t0 = time.perf_counter()
        self._fill_slots()
        if all(s is None or s.done for s in self.slots):
            return {}
        _obs = obs.enabled()
        _t0 = obs.now_ns() if _obs else 0
        logits, self._state = self._step_fn(self._state, self._toks,
                                            jnp.asarray(self._slot_pos))
        if _obs:
            obs.complete("engine.decode_step", _t0, cat="engine", args={
                "active": sum(s is not None and not s.done
                              for s in self.slots),
                "max_batch": self.max_batch})
        self._slot_pos += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        out = {}
        toks = np.asarray(self._toks).copy()
        now = time.perf_counter()
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            if req.t_first_token is None:
                req.t_first_token = now
            out[req.rid] = tok
            toks[i] = tok
            if len(req.out) >= req.max_new_tokens:
                req.done = True
                req.t_done = now
                self.completed.append(req)
        self._toks = jnp.asarray(toks)
        self.step_times_s.append(time.perf_counter() - t0)
        return out

    def drain_completed(self) -> List[Request]:
        """Return finished requests accumulated so far and clear the list
        (fleet routers poll this between slices)."""
        done, self.completed = self.completed, []
        return done

    def run_until_done(self, max_steps: int = 1000) -> List[Request]:
        """Run until queue and slots are exhausted; returns the requests
        that completed during THIS call (a finished request whose slot was
        refilled is kept, not dropped). Earlier completions stay in the
        ``completed`` accumulator until ``drain_completed``."""
        already = len(self.completed)
        for _ in range(max_steps):
            if not self.queue and all(s is None or s.done
                                      for s in self.slots):
                break
            self.step()
        return list(self.completed[already:])
