"""GPU SM-pool re-parameterization of Eq. (1) - DESIGN.md SS.5.

The same placement engine that runs on edge PIM macros (Table III/V) and
TPU chip pools (``serve/hetero.py``) runs here on a GPU whose streaming
multiprocessors are partitioned into two pools pinned at different DVFS
operating points:

- **Clusters**: the HP pool (``n_hp`` SM clusters at the full boost
  clock) and the LP pool (``n_lp`` SM clusters capped at ``lp_clock`` of
  the boost frequency with a proportionally lowered rail voltage) play
  the paper's HP-PIM / LP-PIM roles. ``lp_clock`` is the DVFS sweep knob:
  per-op latency scales as ``1/lp_clock`` while dynamic energy scales as
  :func:`dvfs_energy_scale` (``V^2`` at the frequency-matched voltage),
  which traces the energy-vs-latency frontier.
- **Memory kinds as residency precisions**: bf16 HBM residency is the
  "SRAM" tier (2 bytes fetched per use; a pool holding bf16 shards must
  stay at its operating point, i.e. volatile), fp8/int8 residency is the
  "MRAM" tier (1 byte per use plus a dequant surcharge; a pool holding
  only low-precision shards may drop to retention sleep when idle, i.e.
  non-volatile). ``rho`` is the decode batch size: one weight fetch from
  HBM serves the whole batch step (weight-stationary reuse).

Eq. (1) is isomorphic under this substitution - Algorithms 1/2 only see
per-space ``(t_i, e_i)`` - so ``gpu_arch()`` just builds a
:class:`~repro.core.spaces.PIMArch` from the constants below and the whole
stack (solvers, scheduler, fleet, serve engine) runs unchanged.

This module is import-light on purpose (no jax): the substrate registry
builds archs from it without pulling in the serving runtime.
"""
from __future__ import annotations

import dataclasses

from repro.core import spaces as sp
from repro.core.techmodel import SM_POOL_7NM

# -- A100-class constants (per SM cluster of 16 SMs; estimates, documented)
SMS_PER_CLUSTER = 16
PEAK_FLOPS = 46e12           # bf16 FMA throughput of one SM cluster
HBM_BW = 250e9               # B/s, one cluster's slice of HBM bandwidth
HBM_PJ_PER_BYTE = 6.5        # HBM2e access energy
MAC_PJ = 1.1                 # bf16 MAC incl. operand routing / tensor core
DEQUANT_PJ = 0.3             # fp8/int8 -> bf16 up-convert per weight use
# Pool static power models only the INCREMENTAL cost of keeping the SM
# cluster pinned at its operating point (rail leakage + HBM refresh of the
# resident shard), not whole-board idle draw: decode is memory-bound, so
# the placement trade-off must stay dynamic-dominated for Eq. (1)'s DP
# (which, verbatim from the paper, optimizes dynamic energy only) to
# remain near-optimal - the same regime the edge Table V constants are in.
IDLE_W = 3.5                 # SM cluster pinned at clock, holding bf16
SLEEP_W = 0.5                # retention sleep (fp8/int8-resident pool)
HBM_GB_PER_CLUSTER = 8       # capacity slice per SM cluster

LP_CLOCK = 0.45              # default DVFS point of the low-power pool

#: registered per-tech-node physics of this pool family (DESIGN.md SS.10)
TECH = SM_POOL_7NM
#: rail voltage floor as a fraction of nominal - now owned by the
#: TechModel; kept as a module constant for compatibility
V_MIN_FRAC = TECH.v_min_frac


def dvfs_energy_scale(clock: float) -> float:
    """Dynamic-energy scale at a DVFS frequency scale ``clock``.

    Voltage tracks frequency linearly down to the retention floor
    (``V = V_MIN_FRAC + (1 - V_MIN_FRAC) * clock`` of nominal) and
    switching energy goes as ``V^2`` - the standard DVFS model, and the
    same shape the paper's 1.2 V / 0.8 V HP/LP split instantiates.
    Delegates to the registered :data:`TECH` model
    (:mod:`repro.core.techmodel`), whose arithmetic is byte-identical
    to the historic inline expression.
    """
    return TECH.energy_scale(clock)


def _mem(kind: str, clock: float, energy: float) -> sp.MemorySpec:
    """One residency precision on one pool's HBM slice.

    ``mram`` = fp8/int8 (1 byte/use + dequant, non-volatile analogue),
    ``sram`` = bf16 (2 bytes/use, pool pinned while holding).
    """
    bytes_per_use = 1 if kind == "mram" else 2
    read_ns = bytes_per_use / HBM_BW / clock * 1e9
    read_pj = bytes_per_use * HBM_PJ_PER_BYTE * energy
    if kind == "mram":
        read_pj += DEQUANT_PJ * energy
    static_w = SLEEP_W if kind == "mram" else IDLE_W
    return sp.MemorySpec(
        kind, read_ns=read_ns, write_ns=4 * read_ns,
        read_mw=read_pj / read_ns, write_mw=read_pj / (2 * read_ns),
        static_mw=static_w * 1e3 * energy,       # W -> mW
        volatile=(kind == "sram"),
        capacity_bytes=HBM_GB_PER_CLUSTER * 2 ** 30)


def _pe(clock: float, energy: float) -> sp.PESpec:
    op_s = 2.0 / PEAK_FLOPS / clock              # one MAC = 2 flops
    op_ns = op_s * 1e9
    return sp.PESpec(op_ns=op_ns, dyn_mw=MAC_PJ * energy / op_ns,
                     static_mw=0.0)


def gpu_arch(n_hp_clusters: int = 8, n_lp_clusters: int = 8, *,
             lp_clock: float = LP_CLOCK) -> sp.PIMArch:
    """HP/LP SM-cluster pools x {bf16, fp8/int8} residency as a PIMArch."""
    lp_energy = dvfs_energy_scale(lp_clock)
    hp = sp.ClusterSpec("hp", _pe(1.0, 1.0), n_hp_clusters, ())
    lp = sp.ClusterSpec("lp", _pe(lp_clock, lp_energy), n_lp_clusters, ())

    def spaces_for(c: sp.ClusterSpec, clock: float,
                   energy: float) -> tuple:
        mram = _mem("mram", clock, energy)
        sram = _mem("sram", clock, energy)
        return (
            sp.StorageSpace(f"{c.name}_mram", c.name, mram, sram, c.pe,
                            c.n_modules),
            sp.StorageSpace(f"{c.name}_sram", c.name, sram, sram, c.pe,
                            c.n_modules),
        )

    hp = dataclasses.replace(hp, spaces=spaces_for(hp, 1.0, 1.0))
    lp = dataclasses.replace(lp, spaces=spaces_for(lp, lp_clock, lp_energy))
    return sp.PIMArch("gpu_pool", (hp, lp))
