"""HH-PIM serving runtime on TPU pools - the paper's technique as a
first-class serving feature.

The SAME placement engine (EnergyModel + LUT + TimeSliceScheduler from
``repro.core``) runs here with a TPU parameterization instead of Table
III/V: ``tpu_arch()`` builds a PIMArch whose two clusters are the HP pool
(n_hp chips, full clock) and LP pool (n_lp chips, DVFS-scaled clock/energy)
and whose memory kinds are weight-residency formats - bf16 ("SRAM": 2
HBM-bytes/use, pool pinned on while holding) and int8 ("MRAM": 1 byte/use
plus dequant, pool may sleep when idle). Eq. (1) is isomorphic; only
(t_i, e_i) change. See DESIGN.md SS.3.

``HeteroServeEngine`` actually re-tiers the model weights every time slice
(real re-quantization + column splits via models.hetero_linear) and decodes
through them, so placement changes are functionally exercised, while energy
and latency are accounted by the core model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import spaces as sp
from repro.core.scheduler import SliceReport, TimeSliceScheduler
from repro.models import lm
from repro.models.common import ModelConfig
from repro.models.hetero_linear import (fractions_to_counts, split_weight,
                                        tiered_matmul)

# -- TPU v5e-class constants (per chip; estimates, documented) --------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
HBM_PJ_PER_BYTE = 5.0
MAC_PJ = 0.8                 # bf16 MAC incl. systolic overhead
IDLE_W_PER_CHIP = 60.0       # pool kept powered while holding bf16 shards
SLEEP_W_PER_CHIP = 8.0       # retention sleep (int8/"NVM" analogue)
LP_CLOCK = 0.6               # DVFS-scaled low-power pool
LP_ENERGY = 0.5


def _mem(kind: str, clock: float, energy: float) -> sp.MemorySpec:
    bytes_per_use = 1 if kind == "mram" else 2
    read_s = bytes_per_use / HBM_BW / clock
    read_ns = read_s * 1e9
    read_pj = bytes_per_use * HBM_PJ_PER_BYTE * energy
    static = (SLEEP_W_PER_CHIP if kind == "mram" else IDLE_W_PER_CHIP)
    return sp.MemorySpec(
        kind, read_ns=read_ns, write_ns=4 * read_ns,
        read_mw=read_pj / read_ns, write_mw=read_pj / (2 * read_ns),
        static_mw=static * 1e3 * energy,         # W -> mW
        volatile=(kind == "sram"),
        capacity_bytes=16 * 2 ** 30)             # HBM per chip


def _pe(clock: float, energy: float) -> sp.PESpec:
    op_s = 2.0 / PEAK_FLOPS / clock              # one MAC = 2 flops
    op_ns = op_s * 1e9
    return sp.PESpec(op_ns=op_ns, dyn_mw=MAC_PJ * energy / op_ns,
                     static_mw=0.0)


def tpu_arch(n_hp_chips: int = 4, n_lp_chips: int = 4) -> sp.PIMArch:
    """HP/LP chip pools x {bf16, int8} residency as a PIMArch."""
    hp = sp.ClusterSpec("hp", _pe(1.0, 1.0), n_hp_chips, ())
    lp = sp.ClusterSpec("lp", _pe(LP_CLOCK, LP_ENERGY), n_lp_chips, ())
    def spaces_for(c, clock, energy):
        mram = _mem("mram", clock, energy)
        sram = _mem("sram", clock, energy)
        return (
            sp.StorageSpace(f"{c.name}_mram", c.name, mram, sram, c.pe,
                            c.n_modules),
            sp.StorageSpace(f"{c.name}_sram", c.name, sram, sram, c.pe,
                            c.n_modules),
        )
    hp = dataclasses.replace(hp, spaces=spaces_for(hp, 1.0, 1.0))
    lp = dataclasses.replace(lp, spaces=spaces_for(lp, LP_CLOCK, LP_ENERGY))
    return sp.PIMArch("tpu_hetero", (hp, lp))


# legacy tpu/gpu mapping, kept as the engine fallback when a substrate
# does not publish a tier_plan(): (space, tier, format) in split order
_DEFAULT_TIER_PLAN = (("hp_sram", "hp_bf16", "bf16"),
                      ("hp_mram", "hp_int8", "int8"),
                      ("lp_sram", "lp_bf16", "bf16"),
                      ("lp_mram", "lp_int8", "int8"))
_SPACE_TO_TIER = {s: t for s, t, _ in _DEFAULT_TIER_PLAN}


def default_t_slice_ms(arch: sp.PIMArch, model: sp.ModelSpec, *,
                       rho: float, peak_tasks: int = 10) -> float:
    """Slice sized as the paper sizes T: fits ``peak_tasks`` tasks at peak
    performance, plus 1% headroom to absorb a migration. Shared by
    ``HeteroServeEngine`` and the ``repro.api`` fleet constructors."""
    from repro.core.energy import EnergyModel
    em = EnergyModel(arch, model, rho=rho)
    t_peak = em.task_cost(em.peak_placement(True)).t_task_ns
    return t_peak * peak_tasks * 1.01 / 1e6


def tpu_model_spec(cfg: ModelConfig, tokens_per_task: int) -> sp.ModelSpec:
    """One *task* = decoding `tokens_per_task` tokens for one request."""
    n_params = (cfg.n_layers
                * (3 * cfg.d_model * cfg.d_ff
                   if cfg.mlp_act in ("swiglu", "geglu")
                   else 2 * cfg.d_model * cfg.d_ff))
    n_params += cfg.n_layers * 4 * cfg.d_model * cfg.d_model
    macs = n_params * tokens_per_task
    return sp.ModelSpec(f"{cfg.name}_serve", n_params, macs, 1.0)


@dataclasses.dataclass
class HeteroSliceResult:
    report: SliceReport
    tokens: np.ndarray           # decoded token ids (n_requests,)
    retiered: bool


class HeteroServeEngine:
    """Time-sliced decode engine with placement-driven weight tiering.

    Canonically constructed through ``repro.api.engine("tpu-pool", ...)``;
    the chip-count/rho keywords remain for direct use and are folded into
    a ``tpu-pool`` substrate when none is passed.
    """

    def __init__(self, cfg: ModelConfig, params, *,
                 t_slice_ms: Optional[float] = None,
                 n_hp_chips: int = 4, n_lp_chips: int = 4,
                 tokens_per_task: int = 8, rho: float = 64.0,
                 max_batch: int = 16, peak_tasks: int = 10, seed: int = 0,
                 substrate=None, lut_points: Optional[int] = None,
                 compiler=None):
        from repro.core.substrate import make_substrate
        if substrate is None:
            # rho: weight-stationary reuse on TPU = tokens sharing one
            # weight fetch per batch step (batched decode reads W once)
            substrate = make_substrate(
                "tpu-pool", n_hp_chips=n_hp_chips, n_lp_chips=n_lp_chips,
                tokens_per_task=tokens_per_task, rho=rho,
                peak_tasks=peak_tasks)
        if cfg is None:
            from repro.configs import get_smoke_config
            cfg = get_smoke_config("internlm2_1_8b")
        self.cfg = cfg
        self.params = params
        self.substrate = substrate
        self.arch = substrate.arch
        self.model_spec = substrate.model_spec(cfg)
        if t_slice_ms is None:
            t_slice_ms = substrate.default_t_slice_ns(self.model_spec) / 1e6
        self.t_slice_ms = t_slice_ms
        # a shared PlacementCompiler (api.fleet passes one) makes this
        # engine's LUT builds - including straggler rebuilds - hit the
        # fleet-wide cache
        self.sched = TimeSliceScheduler.from_substrate(
            substrate, self.model_spec, t_slice_ns=t_slice_ms * 1e6,
            lut_points=32 if lut_points is None else lut_points,
            compiler=compiler)
        self.max_batch = max_batch
        # substrate-declared (space, tier, format) split order: the cxl
        # substrates re-tier int8/int8 pairs, cxl-tier-3 a 3-way int8
        # split; tpu/gpu pools keep the legacy bf16/int8 mapping
        plan = getattr(substrate, "tier_plan", None)
        self._tier_plan = tuple(plan()) if plan else _DEFAULT_TIER_PLAN
        self._tiered: Optional[Dict] = None
        self._tiered_placement: Optional[Dict[str, int]] = None
        self._toks = jnp.zeros((max_batch,), jnp.int32)
        self._state = lm.init_decode_state(cfg, max_batch, 128)
        self._pos = 0
        self.history: List[HeteroSliceResult] = []

    # -- weight tiering ----------------------------------------------------
    def _retier(self, placement: Dict[str, int]) -> bool:
        if placement == self._tiered_placement:
            return False
        _obs = obs.enabled()
        _t0 = obs.now_ns() if _obs else 0
        K = self.model_spec.n_params
        space_to_tier = {s: t for s, t, _ in self._tier_plan}
        formats = {t: f for _, t, f in self._tier_plan}
        order = tuple(t for _, t, _ in self._tier_plan)
        tiers = {}
        stack = self.params["stack"]
        for lname, layer in stack.items():
            ffn = layer.get("ffn") if isinstance(layer, dict) else None
            if not ffn:
                continue
            for wname in ("w_up", "w_gate"):
                if wname not in ffn:
                    continue
                w = ffn[wname]
                counts = fractions_to_counts(
                    w.shape[-1],
                    {space_to_tier[k]: v for k, v in placement.items()},
                    K, order=order)
                tiers[(lname, wname)] = split_weight(
                    jnp.asarray(w, jnp.float32),
                    {t: counts.get(t, 0) for t in order}, formats=formats)
        self._tiered = tiers
        self._tiered_placement = dict(placement)
        if _obs:
            # a migration = weights actually re-quantized and re-split
            obs.complete("engine.migration", _t0, cat="engine",
                         args={"placement": dict(placement),
                               "n_weights": len(tiers)})
            obs.counter("engine.migrations")
        return True

    def apply_placement(self, placement: Dict[str, int]) -> bool:
        """Re-tier the model weights to ``placement`` (no-op if unchanged).
        Returns True when a migration actually happened. Fleet routers call
        this with the placement chosen by an externally-driven scheduler."""
        return self._retier(placement)

    def decode(self, n_requests: int) -> np.ndarray:
        """Decode one token for ``n_requests`` active requests (public fleet
        entry point; capped at ``max_batch``)."""
        if n_requests <= 0:
            return np.zeros((0,), np.int32)
        return self._decode_tokens(min(n_requests, self.max_batch))

    def _decode_tokens(self, n_requests: int) -> np.ndarray:
        """Decode one token per active request through the tiered model."""
        _obs = obs.enabled()
        _t0 = obs.now_ns() if _obs else 0
        logits, self._state = lm.decode_step(
            self.params, self.cfg, self._state, self._toks,
            jnp.int32(self._pos))
        if _obs:
            obs.complete("engine.decode", _t0, cat="engine",
                         args={"n_requests": n_requests})
        # tiered verification path: run the first tiered FFN on the final
        # hidden state proxy to exercise placement-dependent compute
        self._pos += 1
        toks = np.asarray(jnp.argmax(logits, axis=-1))[:n_requests]
        self._toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return toks

    def run_slice(self, n_requests: int, *,
                  lookup_tasks: Optional[int] = None,
                  cap_to_capacity: bool = False) -> HeteroSliceResult:
        """One time slice. ``lookup_tasks`` consults the placement LUT on a
        predicted load instead of the actual backlog (proactive migration);
        ``cap_to_capacity`` executes only what fits in the slice (the report's
        ``n_executed``), for fleet-style carryover queueing."""
        n_tasks = int(np.ceil(n_requests))
        report = self.sched.step(n_tasks, lookup_tasks=lookup_tasks,
                                 cap_to_capacity=cap_to_capacity)
        retiered = self._retier(report.placement)
        toks = self._decode_tokens(min(report.n_done, self.max_batch)) \
            if report.n_done else np.zeros((0,), np.int32)
        res = HeteroSliceResult(report, toks, retiered)
        self.history.append(res)
        return res

    def tiered_forward(self, x: jnp.ndarray, layer: str = None):
        """Run one tiered FFN matmul (placement-split) - used by tests to
        check placement invariance of the math."""
        assert self._tiered, "run_slice first"
        key = next(iter(self._tiered))
        return tiered_matmul(x, self._tiered[key])

    # -- summaries ----------------------------------------------------------
    def energy_uj(self) -> float:
        return sum(r.report.energy_pj for r in self.history) * 1e-6

    def deadline_misses(self) -> int:
        return sum(not r.report.deadline_met for r in self.history)
