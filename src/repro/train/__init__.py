from repro.train.step import make_train_step, default_optimizer_kind
__all__ = ["make_train_step", "default_optimizer_kind"]
