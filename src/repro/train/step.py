"""jit-able training step: microbatched grad accumulation + optimizer.

The microbatch loop is a ``lax.scan`` whose body ends in the gradient
accumulation add - XLA's latency-hiding scheduler can overlap microbatch
i's gradient reduce-scatter with microbatch i+1's compute (DESIGN.md SS.6).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.common import ModelConfig
from repro.optim.adamw import Optimizer

PyTree = Any


def default_optimizer_kind(cfg: ModelConfig) -> str:
    """Arctic-class models need factored moments to fit 16 GB/chip."""
    if cfg.n_experts >= 64:
        return "adafactor"
    return "adamw"


def default_train_memory_plan(cfg: ModelConfig, global_batch: int
                              ) -> Dict[str, Any]:
    """Microbatch count + grad-accumulation dtype per model scale."""
    big = cfg.d_model >= 5120 or cfg.n_experts >= 16
    micro = 16 if big else 8
    while global_batch % micro:
        micro //= 2
    return {"num_microbatches": max(micro, 1),
            "accum_dtype": jnp.bfloat16 if big else jnp.float32}


def make_loss_fn(cfg: ModelConfig) -> Callable:
    def loss(params, batch):
        return lm.loss_fn(params, cfg, batch)
    return loss


def make_train_step(cfg: ModelConfig, opt: Optimizer,
                    num_microbatches: int = 1,
                    accum_dtype=jnp.float32) -> Callable:
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params: PyTree, opt_state: PyTree, batch: PyTree
                   ) -> Tuple[PyTree, PyTree, Dict[str, jnp.ndarray]]:
        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                n = num_microbatches
                return x.reshape((n, x.shape[0] // n) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                gacc, lacc = carry
                (loss_mb, _m), g = grad_fn(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), gacc, g)
                return (gacc, lacc + loss_mb), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (gsum, lsum), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)),
                                           micro)
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32)
                           / num_microbatches).astype(accum_dtype), gsum)
            loss = lsum / num_microbatches
            metrics = {}

        new_params, new_opt_state = opt.update(grads, opt_state, params)
        out_metrics = {"loss": loss}
        out_metrics.update({k: v for k, v in metrics.items()
                            if k in ("aux",)})
        return new_params, new_opt_state, out_metrics

    return train_step
