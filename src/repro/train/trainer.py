"""Trainer: jit train_step + data pipeline + fault-tolerant checkpointing.

Production behaviors folded in:
  * deterministic resume (data batch i = f(seed, i), optimizer step in the
    checkpoint),
  * async, atomic checkpoints every ``ckpt_every`` steps + final sync save,
  * preemption hook: ``request_stop()`` (wired to SIGTERM by launch.train)
    checkpoints and exits cleanly at the next step boundary,
  * optional int8+error-feedback gradient compression across the DP
    reduction (cross-pod DCI saver),
  * per-step wall-time tracking with a straggler log (steps > 2x median).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import lm
from repro.models.common import ModelConfig
from repro.optim.adamw import OptimizerConfig, make_optimizer
from repro.optim.compression import (compress_with_feedback,
                                     init_error_state)
from repro.train.step import make_loss_fn

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    grad_compression: bool = False
    seed: int = 0


class Trainer:
    def __init__(self, model_cfg: ModelConfig, opt_cfg: OptimizerConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig):
        self.model_cfg = model_cfg
        self.tcfg = tcfg
        self.opt = make_optimizer(opt_cfg)
        self.data = SyntheticLM(data_cfg)
        self.loss_fn = make_loss_fn(model_cfg)
        self._stop = False
        self.step_times: List[float] = []
        self.metrics_log: List[Dict[str, float]] = []

        key = jax.random.PRNGKey(tcfg.seed)
        self.params = lm.init_lm(key, model_cfg)
        self.opt_state = self.opt.init(self.params)
        self.error_state = (init_error_state(self.params)
                            if tcfg.grad_compression else None)
        self.step = 0

        grad_fn = jax.value_and_grad(self.loss_fn, has_aux=True)

        def train_step(params, opt_state, error_state, batch):
            (loss, metrics), grads = grad_fn(params, batch)
            if tcfg.grad_compression:
                grads, error_state = compress_with_feedback(grads,
                                                            error_state)
            new_params, new_opt = self.opt.update(grads, opt_state, params)
            return new_params, new_opt, error_state, loss

        self._jit_step = jax.jit(train_step, donate_argnums=(0, 1, 2))
        self._ckpt = (ckpt.AsyncCheckpointer(tcfg.ckpt_dir)
                      if tcfg.ckpt_dir else None)

    # -- fault tolerance ---------------------------------------------------
    def request_stop(self) -> None:
        self._stop = True

    def maybe_resume(self) -> bool:
        if not self.tcfg.ckpt_dir:
            return False
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return False
        state = ckpt.restore({"params": self.params,
                              "opt": self.opt_state,
                              "step": np.zeros((), np.int32)},
                             self.tcfg.ckpt_dir, last)
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = int(state["step"])
        return True

    def _save(self, final: bool = False) -> None:
        if not self._ckpt:
            return
        tree = {"params": self.params, "opt": self.opt_state,
                "step": np.int32(self.step)}
        self._ckpt.save_async(tree, self.step)
        if final:
            self._ckpt.wait()

    # -- loop ---------------------------------------------------------------
    def run(self) -> Dict[str, float]:
        while self.step < self.tcfg.steps and not self._stop:
            batch_np = self.data.batch(self.step)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            t0 = time.perf_counter()
            (self.params, self.opt_state, self.error_state,
             loss) = self._jit_step(self.params, self.opt_state,
                                    self.error_state, batch)
            loss = float(loss)
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            self.step += 1
            self.metrics_log.append({"step": self.step, "loss": loss,
                                     "sec": dt})
            if self.step % self.tcfg.ckpt_every == 0:
                self._save()
        self._save(final=True)
        med = float(np.median(self.step_times)) if self.step_times else 0.0
        stragglers = sum(t > 2 * med for t in self.step_times[1:])
        return {"final_loss": self.metrics_log[-1]["loss"],
                "first_loss": self.metrics_log[0]["loss"],
                "steps": self.step, "median_step_s": med,
                "straggler_steps": stragglers}
