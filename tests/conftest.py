"""Shared test helpers.

``hypothesis`` is an optional dependency (CI runs a tier-1 job without
it): test modules import ``given``/``settings``/``st`` from here so that
without hypothesis the property-based tests skip cleanly while every
deterministic test still runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:                      # pragma: no cover - optional dep
    def _skip_property_test(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="property tests need hypothesis")(fn)
        return deco
    given = settings = _skip_property_test

    class _AnyStrategy:
        """Chainable stand-in so strategy expressions in decorator
        arguments (st.integers(1, 5).map(...) etc.) evaluate harmlessly
        at collection time."""

        def __getattr__(self, _name):
            return lambda *a, **k: _AnyStrategy()

    st = _AnyStrategy()
