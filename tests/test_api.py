"""Tests for the repro.api facade: substrate/solver registries,
equivalence of the facade construction path with the canonical
``from_substrate`` constructor, and the removal of the retired
one-release deprecation shims."""
import warnings

import pytest

from repro import api
from repro.core import spaces as sp
from repro.core import workloads
from repro.core.scheduler import FixedPlacementScheduler, TimeSliceScheduler
from repro.core.system import default_t_slice_ns

RHO = 4.0

EDGE_SUBSTRATES = ("edge-hhpim", "edge-hetero", "edge-hybrid",
                   "edge-baseline")
TPU_SUBSTRATES = ("tpu-pool", "tpu-pool-mixed")
GPU_SUBSTRATES = ("gpu-pool", "gpu-pool-mixed")
CXL_SUBSTRATES = ("cxl-tier", "cxl-tier-3", "cxl-tier-3-mixed")
FIXED_SOLVERS = ("fixed-baseline", "fixed-hetero", "fixed-hybrid")


# -- registries --------------------------------------------------------------


def test_registries_cover_issue_contract():
    assert set(api.SUBSTRATES) >= (set(EDGE_SUBSTRATES)
                                   | set(TPU_SUBSTRATES)
                                   | set(GPU_SUBSTRATES)
                                   | set(CXL_SUBSTRATES))
    assert set(api.SOLVERS) >= {"dp", "closed-form", *FIXED_SOLVERS}
    with pytest.raises(ValueError):
        api.substrate("edge-nope")
    with pytest.raises(ValueError):
        api.solver("simulated-annealing")


def test_list_substrates_matches_registry():
    names = api.list_substrates()
    assert names == tuple(sorted(api.SUBSTRATES))
    assert set(GPU_SUBSTRATES) <= set(names)


@pytest.mark.parametrize("name", EDGE_SUBSTRATES)
def test_every_edge_substrate_schedules_a_slice(name):
    sched = api.scheduler(name, sp.MOBILENET_V2, rho=RHO, lut_points=8)
    rep = sched.step(2)
    assert rep.n_tasks == 2
    assert rep.energy_pj > 0
    # dynamic HH-PIM gets the migrating runtime, fixed policies don't
    if name == "edge-hhpim":
        assert isinstance(sched, TimeSliceScheduler)
    else:
        assert isinstance(sched, FixedPlacementScheduler)


def test_substrate_overrides_reach_the_factory():
    sub = api.substrate("tpu-pool", n_hp_chips=2, n_lp_chips=6)
    assert sub.arch.cluster("hp").n_modules == 2
    assert sub.arch.cluster("lp").n_modules == 6
    small = api.substrate("tpu-pool-mixed").engine_variant(1)
    assert small.arch.cluster("hp").n_modules == 2


def test_fixed_solvers_build_single_entry_luts():
    sub = api.substrate("edge-hybrid")
    for name in FIXED_SOLVERS:
        lut = sub.build_lut(sp.EFFICIENTNET_B0, solver=name,
                            t_slice_ns=1e9, rho=RHO)
        assert len(lut.entries) == 1 and lut.entries[0].feasible
        assert lut.lookup(1e9).placement == lut.entries[0].placement


# -- equivalence: facade path vs the canonical constructor -------------------


def test_edge_hhpim_lut_and_reports_match_from_substrate():
    m = sp.EFFICIENTNET_B0
    T = default_t_slice_ns(m, RHO)
    ref = TimeSliceScheduler.from_substrate(
        api.substrate("edge-hhpim", rho=RHO), m, t_slice_ns=T, rho=RHO,
        lut_points=24)
    new = api.scheduler("edge-hhpim", m, t_slice_ns=T, rho=RHO,
                        lut_points=24)
    assert ref.lut.entries == new.lut.entries     # byte-identical LUT
    loads = workloads.SCENARIOS["case6_random"][:12]
    assert [ref.step(n) for n in loads] == [new.step(n) for n in loads]


def test_tpu_pool_lut_and_reports_match_from_substrate():
    from repro.configs import get_smoke_config
    from repro.serve.hetero import (default_t_slice_ms, tpu_arch,
                                    tpu_model_spec)
    cfg = get_smoke_config("internlm2_1_8b")
    sub = api.substrate("tpu-pool", tokens_per_task=2)
    model = sub.model_spec(cfg)
    # the substrate's sizing matches the serve-layer helper it wraps
    T = default_t_slice_ms(tpu_arch(), tpu_model_spec(cfg, 2), rho=64.0,
                           peak_tasks=10) * 1e6
    ref = TimeSliceScheduler.from_substrate(sub, model, t_slice_ns=T,
                                            lut_points=32)
    new = api.scheduler("tpu-pool", cfg, tokens_per_task=2, lut_points=32)
    assert new.t_slice_ns == pytest.approx(T, rel=0, abs=0)
    assert ref.lut.entries == new.lut.entries
    assert [ref.step(n) for n in (4, 1, 8)] == \
        [new.step(n) for n in (4, 1, 8)]


def test_gpu_pool_lut_matches_direct_substrate_build():
    """The facade path and a hand-held GPUPoolSubstrate agree bit-for-bit
    (the gpu analogue of the tpu legacy-equivalence test; the legacy
    keyword constructor cannot express the pool's t_slice static window,
    so the substrate build is the reference)."""
    from repro.configs import get_smoke_config
    from repro.serve.gpu import gpu_arch
    cfg = get_smoke_config("internlm2_1_8b")
    sub = api.substrate("gpu-pool", tokens_per_task=2)
    assert sub.arch.name == gpu_arch().name
    model = sub.model_spec(cfg)
    T = sub.default_t_slice_ns(model)
    lut = sub.build_lut(model, t_slice_ns=T, n_points=32)
    sched = api.scheduler("gpu-pool", cfg, tokens_per_task=2,
                          lut_points=32)
    assert sched.t_slice_ns == pytest.approx(T, rel=0, abs=0)
    assert sched.lut.entries == lut.entries          # byte-identical LUT
    reports = [sched.step(n) for n in (4, 1, 8)]
    assert all(r.energy_pj > 0 for r in reports)
    assert reports[0].n_tasks == 4


def test_gpu_pool_dvfs_knob_reaches_factory_and_variants():
    sub = api.substrate("gpu-pool", n_hp_clusters=2, n_lp_clusters=6,
                        lp_clock=0.8)
    assert sub.arch.cluster("hp").n_modules == 2
    assert sub.arch.cluster("lp").n_modules == 6
    assert sub.lp_clock == 0.8
    small = api.substrate("gpu-pool-mixed").engine_variant(1)
    assert small.n_hp_clusters == 4 and small.n_lp_clusters == 4
    # lp_clock is part of the LUT-sharing key: engines at different DVFS
    # points must not share a LUT
    assert (api.substrate("gpu-pool", lp_clock=0.3).variant_key()
            != api.substrate("gpu-pool", lp_clock=0.9).variant_key())


def test_gpu_pool_dp_and_closed_form_agree():
    """Acceptance: the verbatim Algorithm 1+2 DP and the closed-form
    solver agree on the gpu-pool backend within the solver-agreement
    tolerance, with identical deadline behaviour."""
    sub = api.substrate("gpu-pool", tokens_per_task=2)
    model = sub.model_spec()
    T = sub.default_t_slice_ns(model)
    for scen in ("case3_periodic_spike", "case6_random"):
        loads = workloads.SCENARIOS[scen]
        res = {}
        for solver in ("closed-form", "dp"):
            sched = api.scheduler(sub, model, t_slice_ns=T, lut_points=24,
                                  solver=solver)
            reports = sched.run(loads)
            res[solver] = (sum(r.energy_pj for r in reports),
                           sum(not r.deadline_met for r in reports))
        cf, dp = res["closed-form"], res["dp"]
        assert dp[1] == cf[1], scen
        assert dp[0] == pytest.approx(cf[0], rel=0.10), scen


def test_gpu_pool_dvfs_scale_is_monotone():
    """DVFS property: raising the LP-pool frequency scale strictly
    shortens LP per-op latency and strictly raises LP per-op energy
    (V^2 at the frequency-matched voltage); the HP pool is untouched and
    the substrate's peak latency improves monotonically."""
    clocks = (0.3, 0.45, 0.6, 0.8, 1.0)
    subs = [api.substrate("gpu-pool", lp_clock=c, tokens_per_task=2)
            for c in clocks]
    model = subs[0].model_spec()
    for kind in ("sram", "mram"):
        t = [s.arch.cluster("lp").space(kind).op_ns(s.rho) for s in subs]
        e = [s.arch.cluster("lp").space(kind).op_pj(s.rho) for s in subs]
        assert all(a > b for a, b in zip(t, t[1:])), (kind, t)
        assert all(a < b for a, b in zip(e, e[1:])), (kind, e)
        t_hp = [s.arch.cluster("hp").space(kind).op_ns(s.rho) for s in subs]
        assert len(set(t_hp)) == 1
    t_peak = []
    for s in subs:
        em = s.energy_model(model)
        t_peak.append(em.task_cost(em.peak_placement(True)).t_task_ns)
    assert all(a > b for a, b in zip(t_peak, t_peak[1:])), t_peak
    with pytest.raises(ValueError):
        api.substrate("gpu-pool", lp_clock=0.0)
    with pytest.raises(ValueError):
        api.substrate("gpu-pool", lp_clock=1.5)


def test_fixed_substrates_match_legacy_policies():
    from repro.core.baselines import (baseline_policy, hetero_policy,
                                      hybrid_policy)
    m = sp.RESNET_18
    for name, policy in (("edge-baseline", baseline_policy(m)[1]),
                         ("edge-hetero", hetero_policy(m, RHO)[1]),
                         ("edge-hybrid", hybrid_policy(m)[1])):
        sched = api.scheduler(name, m, rho=RHO)
        assert sched.placement == policy, name


def test_dp_and_closed_form_agree_on_paper_cases():
    """The verbatim Algorithm 1+2 DP and the closed-form solver, selected
    by registry name, agree on the paper's six workload cases: identical
    deadline behaviour and energy within the DP's tick-quantization slack."""
    from repro.core.system import run_hh_pim
    m = sp.EFFICIENTNET_B0
    for scen in workloads.SCENARIOS:
        cf = run_hh_pim(m, scen, rho=RHO, lut_points=24,
                        solver="closed-form")
        dp = run_hh_pim(m, scen, rho=RHO, lut_points=24, solver="dp")
        assert cf.deadline_miss == dp.deadline_miss == 0, scen
        assert dp.energy_uj == pytest.approx(cf.energy_uj, rel=0.10), scen


def test_api_fleet_registry_name_matches_substrate_instance():
    from repro.fleet import summarize
    from repro.fleet.traces import replay_trace
    by_name = api.fleet("tpu-pool-mixed", n_engines=2, forecaster="none")
    by_inst = api.fleet(api.substrate("tpu-pool-mixed", tokens_per_task=2),
                        n_engines=2, forecaster="none")
    s_name = summarize(by_name.run(replay_trace([8, 8, 8, 8])))
    s_inst = summarize(by_inst.run(replay_trace([8, 8, 8, 8])))
    assert s_name == s_inst


# -- batched placement compiler ----------------------------------------------


@pytest.mark.parametrize("method", ["closed_form", "dp"])
@pytest.mark.parametrize("name", api.list_substrates())
def test_batched_lut_is_byte_identical_to_loop(name, method):
    """The batched drivers (vectorized closed-form solve over the whole
    t-grid; full-table Algorithm-2 combine for dp) must produce LUTs
    byte-identical to the per-point loop, for every registered substrate
    and both solver methods."""
    from repro.core.placement import build_lut
    sub = api.substrate(name)
    model = sub.model_spec()
    T = sub.default_t_slice_ns(model)
    em = sub.energy_model(model)
    kw = dict(t_slice_ns=T, n_points=6, k_groups=64, em=em, method=method,
              static_window=sub.static_window)
    batched = build_lut(sub.arch, model, batched=True, **kw)
    loop = build_lut(sub.arch, model, batched=False, **kw)
    assert batched.entries == loop.entries, (name, method)


def test_compiler_dedupes_fleet_shapes_and_serves_cache_hits():
    pc = api.compiler()
    sub = api.substrate("tpu-pool-mixed")
    variants = [sub.engine_variant(i) for i in range(6)]
    model = sub.model_spec()
    T = sub.default_t_slice_ns(model)
    luts = pc.compile(variants, model, t_slice_ns=T, n_points=8)
    # 6 engines, 2 distinct shapes -> 2 builds, one LUT per shape
    assert len(luts) == 2
    stats = pc.stats()
    backends = stats.pop("builds_by_backend")
    assert stats == {"entries": 2, "builds": 2, "hits": 0, "loaded": 0}
    # every build is attributed to the engine that ran it ("host" for
    # the closed-form path, the resolved lut_pipeline backend for dp)
    assert sum(backends.values()) == 2
    # a second fleet on the same shapes is served entirely from cache
    again = pc.compile(variants, model, t_slice_ns=T, n_points=8)
    assert pc.n_builds == 2 and pc.n_hits == 2
    for key, lut in luts.items():
        assert again[key] is lut


def test_compiler_lut_matches_direct_solver_build():
    from repro.core.solvers import make_solver
    sub = api.substrate("edge-hhpim", rho=RHO)
    model = sub.model_spec(sp.EFFICIENTNET_B0)
    em = sub.energy_model(model)
    T = sub.default_t_slice_ns(model)
    pc = api.compiler()
    # variant_key addresses the cache entry; substrate-routed builds
    # (api.lut / schedulers) use substrate.variant_key()
    via_compiler = pc.lut(em, solver="closed-form", t_slice_ns=T,
                          n_points=12, variant_key=sub.variant_key())
    direct = make_solver("closed-form").build_lut(em, t_slice_ns=T,
                                                  n_points=12)
    assert via_compiler.entries == direct.entries
    # api.lut with a compiler routes through (and fills) the same cache
    assert api.lut(sub, model, t_slice_ns=T, n_points=12,
                   compiler=pc).entries == direct.entries
    assert pc.n_hits == 1


def test_compiler_distinguishes_edge_arch_overrides():
    """Edge substrates built with different arch kwargs must not collide
    in a shared compiler cache: the default variant_key fingerprints the
    arch's space shaping."""
    pc = api.compiler()
    m = sp.EFFICIENTNET_B0
    full = api.substrate("edge-hhpim", rho=RHO)
    small = api.substrate("edge-hhpim", rho=RHO, n_hp=2)
    assert full.variant_key() != small.variant_key()
    T = full.default_t_slice_ns(m)      # reference-arch sizing: shared
    lut_full = api.lut(full, m, t_slice_ns=T, n_points=8, compiler=pc)
    lut_small = api.lut(small, m, t_slice_ns=T, n_points=8, compiler=pc)
    assert pc.n_builds == 2 and pc.n_hits == 0
    assert lut_small.entries == small.build_lut(
        m, t_slice_ns=T, n_points=8).entries
    assert lut_full.entries != lut_small.entries


def test_fleet_shares_straggler_rebuilds_through_compiler():
    """Two same-shape engines observing the same slowdown signature must
    pay one LUT rebuild between them (the compiler keys on slowdown)."""
    from repro.fleet.traces import replay_trace
    pc = api.compiler()
    fl = api.fleet("tpu-pool", n_engines=2, forecaster="none", compiler=pc)
    fl.run(replay_trace([2]))
    builds_before = pc.n_builds
    fl.workers[0].sched.observe_slowdown("lp", 1.5)
    _ = fl.workers[0].sched.lut          # rebuild for the new signature
    assert pc.n_builds == builds_before + 1
    fl.workers[1].sched.observe_slowdown("lp", 1.5)
    _ = fl.workers[1].sched.lut          # same shape + signature: cache hit
    assert pc.n_builds == builds_before + 1
    # a *different* slowdown still gets its own entry
    fl.workers[1].sched.observe_slowdown("lp", 2.0)
    _ = fl.workers[1].sched.lut
    assert pc.n_builds == builds_before + 2


def test_fleet_with_compiler_matches_fleet_without():
    from repro.fleet import summarize
    from repro.fleet.traces import replay_trace
    plain = api.fleet("tpu-pool-mixed", n_engines=2, forecaster="none")
    shared = api.fleet("tpu-pool-mixed", n_engines=2, forecaster="none",
                       compiler=api.compiler())
    s_plain = summarize(plain.run(replay_trace([8, 8, 8, 8])))
    s_shared = summarize(shared.run(replay_trace([8, 8, 8, 8])))
    assert s_plain == s_shared


# -- retired deprecation shims (one-release window elapsed) ------------------


def test_direct_scheduler_construction_is_removed():
    m = sp.EFFICIENTNET_B0
    T = default_t_slice_ns(m, RHO)
    with pytest.raises(TypeError, match="from_substrate"):
        TimeSliceScheduler(sp.hh_pim(), m, t_slice_ns=T, rho=RHO,
                           lut_points=8)


def test_make_baseline_scheduler_is_removed():
    import repro.core.baselines as baselines
    assert not hasattr(baselines, "make_baseline_scheduler")
    with pytest.raises(ImportError):
        from repro.core.baselines import make_baseline_scheduler  # noqa


def test_build_fleet_is_removed():
    import repro.fleet as fleet_pkg
    assert not hasattr(fleet_pkg, "build_fleet")
    with pytest.raises(ImportError):
        from repro.fleet import build_fleet  # noqa


def test_facade_path_emits_no_deprecation_warnings():
    from repro.fleet.traces import replay_trace
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        api.scheduler("edge-hhpim", sp.MOBILENET_V2, rho=RHO,
                      lut_points=8).step(2)
        api.fleet("tpu-pool", n_engines=1,
                  forecaster="none").run(replay_trace([2]))
    ours = [w for w in rec if issubclass(w.category, DeprecationWarning)
            and "deprecated" in str(w.message).lower()
            and "repro" in str(w.filename)]
    assert ours == []
