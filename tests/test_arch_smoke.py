"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-grad step + one decode step on CPU; asserts shapes and no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) - see repro.launch.dryrun.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import lm
from repro.models.common import ModelConfig


def _batch_for(cfg: ModelConfig, B=2, S=16, key=None):
    key = key or jax.random.PRNGKey(0)
    kt, kl, kp, ke = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
    }
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = jax.random.normal(
            kp, (B, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        batch["enc_frames"] = jax.random.normal(
            ke, (B, max(S // cfg.enc_len_divisor, 1), cfg.d_model),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_lm(jax.random.PRNGKey(1), cfg)
    batch = _batch_for(cfg)
    B, S = batch["tokens"].shape

    logits, aux = jax.jit(
        lambda p, b: lm.forward(p, cfg, b["tokens"],
                                prefix_embeds=b.get("prefix_embeds"),
                                enc_frames=b.get("enc_frames")))(params,
                                                                 batch)
    P = cfg.n_prefix_embeds if cfg.n_prefix_embeds else 0
    assert logits.shape == (B, S + P, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch)[0]))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # at least one nonzero gradient per model
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_lm(jax.random.PRNGKey(2), cfg)
    B, max_len = 2, 32
    enc_out = None
    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.PRNGKey(3),
                                   (B, 8, cfg.d_model), jnp.float32)
        enc_out = lm.encode(params, cfg, frames)
    state = lm.init_decode_state(cfg, B, max_len, enc_out=enc_out)
    toks = jnp.array([1, 2], dtype=jnp.int32)
    step = jax.jit(lambda s, t, p: lm.decode_step(params, cfg, s, t, p))
    for t in range(3):
        logits, state = step(state, toks, jnp.int32(t))
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["internlm2_1_8b", "recurrentgemma_2b",
                                  "xlstm_1_3b"])
def test_decode_matches_forward(arch):
    """Step-by-step decode must agree with the full-sequence forward
    (KV-cache / recurrent-state consistency)."""
    cfg = get_smoke_config(arch)
    params = lm.init_lm(jax.random.PRNGKey(4), cfg)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                              cfg.vocab_size)
    full_logits, _ = lm.forward(params, cfg, toks)
    state = lm.init_decode_state(cfg, B, max_len=S)
    outs = []
    for t in range(S):
        lg, state = lm.decode_step(params, cfg, state, toks[:, t],
                                   jnp.int32(t))
        outs.append(lg)
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_full_configs_match_assignment():
    """Exact structural constants from the assignment table."""
    spec = {
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen25_32b": (64, 5120, 40, 8, 27648, 152064),
        "internlm2_1_8b": (24, 2048, 16, 8, 8192, 92544),
        "chatglm3_6b": (28, 4096, 32, 2, 13696, 65024),
        "phi3_medium_14b": (40, 5120, 40, 10, 17920, 100352),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
        "pixtral_12b": (40, 5120, 32, 8, 14336, 131072),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "llama4_scout_17b_a16e": (48, 5120, 40, 8, 8192, 202048),
        "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == V, arch
    assert get_config("arctic_480b").n_experts == 128
    assert get_config("arctic_480b").experts_per_token == 2
    assert get_config("llama4_scout_17b_a16e").n_experts == 16
    assert get_config("llama4_scout_17b_a16e").experts_per_token == 1
    # sub-quadratic flags drive the long_500k skip rule
    assert get_config("recurrentgemma_2b").is_subquadratic
    assert get_config("xlstm_1_3b").is_subquadratic
    assert not get_config("qwen25_32b").is_subquadratic
