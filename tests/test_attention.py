"""Chunked (flash-style) attention must match the dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models.common import ModelConfig


def _cfg(attn_kind="full", window=64, heads=4, kv=2, hd=16):
    return ModelConfig(name="t", family="dense", n_layers=1, d_model=heads*hd,
                       n_heads=heads, n_kv_heads=kv, d_ff=1, vocab_size=16,
                       head_dim=hd, attn_kind=attn_kind, local_window=window,
                       dtype=jnp.float32)


def _qkv(cfg, B=2, S=2048, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, cfg.n_heads, cfg.hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, cfg.n_kv_heads, cfg.hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, cfg.n_kv_heads, cfg.hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("S", [1024, 2048])
def test_chunked_causal_matches_dense(S):
    cfg = _cfg()
    q, k, v = _qkv(cfg, S=S)
    dense = A._sdpa(q, k, v, A._causal_mask(S, S), cfg)
    chunked = A._chunked_causal_sdpa(q, k, v, cfg, 512, 512)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_chunked_bidirectional_matches_dense():
    cfg = _cfg()
    q, k, v = _qkv(cfg, S=1024, seed=1)
    dense = A._sdpa(q, k, v, None, cfg)
    chunked = A._chunked_causal_sdpa(q, k, v, cfg, 512, 512, causal=False)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [64, 256])
def test_local_windowed_matches_dense(window):
    cfg = _cfg(attn_kind="local", window=window)
    q, k, v = _qkv(cfg, S=2048, seed=2)
    dense = A._sdpa(q, k, v, A._causal_mask(2048, 2048, window), cfg)
    local = A._local_windowed_sdpa(q, k, v, cfg, 512)
    np.testing.assert_allclose(np.asarray(local), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_chunked_is_differentiable():
    cfg = _cfg()
    q, k, v = _qkv(cfg, S=1024, seed=3)

    def f(q):
        return A._chunked_causal_sdpa(q, k, v, cfg, 256, 256).sum()

    g = jax.grad(f)(q)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).max()) > 0
