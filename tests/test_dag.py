"""Tests for repro.fleet.dag (DESIGN.md SS.11): DAG workload model +
tenant registry validation, seeded trace determinism, the topological
frontier property, stage co-scheduling vs request-level routing, the
zero-extra-LUT-builds pin, per-tenant observability and the CLI entry.
"""
import json

import pytest

from conftest import given, settings, st
from repro import api, obs
from repro.fleet import (DAG_SPECS, DagFleet, DagSpec, StageSpec, Tenant,
                         TenantRegistry, dag_arrivals, default_tenants,
                         make_dag_spec, make_trace, summarize,
                         tenant_breakdown)
from repro.fleet.dag import (DEFAULT_DAG_BUDGETS, DONE, PENDING,
                             REASON_TENANT_BUDGET, dag_budget_slices)


def _small_fleet(**kw):
    kw.setdefault("n_cells", 2)
    kw.setdefault("engines_per_cell", 1)
    kw.setdefault("seed", 0)
    return api.dag_fleet(["tpu-pool", "gpu-pool"], **kw)


def _small_trace(fleet, n_slices=10, seed=0):
    return dag_arrivals(fleet.tenants, n_slices=n_slices, base="poisson",
                        seed=seed, rate=1.0)


# -- spec validation ---------------------------------------------------------


def test_canonical_specs_validate_and_expose_shape():
    for name, spec in DAG_SPECS.items():
        assert make_dag_spec(name) is spec
        assert spec.topo_order()[0] in spec.roots()
        assert spec.critical_path_len() >= 1
    ag = DAG_SPECS["agentic"]
    assert ag.topo_order() == ["prefill", "decode", "tool_call", "decode2"]
    assert ag.critical_path_len() == 4
    assert ag.parents("decode2") == ["tool_call"]
    assert ag.children("prefill") == ["decode"]


def test_unknown_spec_and_stage_raise_shaped_errors():
    with pytest.raises(ValueError, match=r"unknown dag spec 'nope'.*"
                                         r"registered.*prefill_decode"):
        make_dag_spec("nope")
    with pytest.raises(ValueError, match="unknown stage"):
        DAG_SPECS["agentic"].stage("missing")


def test_spec_rejects_duplicates_dangling_edges_and_self_edges():
    with pytest.raises(ValueError, match="duplicate stage names"):
        DagSpec("d", (StageSpec("a", 1), StageSpec("a", 1)))
    with pytest.raises(ValueError, match="unknown stage"):
        DagSpec("d", (StageSpec("a", 1),), (("a", "ghost"),))
    with pytest.raises(ValueError, match="self-edge"):
        DagSpec("d", (StageSpec("a", 1),), (("a", "a"),))
    with pytest.raises(ValueError, match="tokens > 0"):
        StageSpec("a", 0)


def test_cycle_raises_shaped_error_naming_members():
    with pytest.raises(ValueError, match=r"cycle through stages.*'a'.*'b'"):
        DagSpec("d", (StageSpec("a", 1), StageSpec("b", 1)),
                (("a", "b"), ("b", "a")))


# -- tenants -----------------------------------------------------------------


def test_tenant_registry_shaped_errors():
    reg = default_tenants()
    with pytest.raises(ValueError, match=r"unknown tenant 'ghost'.*acme"):
        reg.get("ghost")
    with pytest.raises(ValueError, match="already registered"):
        reg.register(Tenant("acme"))
    with pytest.raises(ValueError, match="weight > 0"):
        Tenant("t", weight=0)
    with pytest.raises(ValueError, match="unknown dag spec"):
        Tenant("t", dag="ghost_spec")


def test_dag_fleet_rejects_unregistered_slo_class():
    with pytest.raises(ValueError, match=r"unknown SLO class \(tenant "
                                         r"'acme'\) 'interactive'"):
        _small_fleet(budgets={"batch": 8.0})


def test_cell_router_budget_is_strict():
    f = _small_fleet()
    with pytest.raises(ValueError, match="unknown SLO class 'nope'"):
        f.router.budget("nope")
    assert f.router.budget("interactive") == \
        DEFAULT_DAG_BUDGETS["interactive"]


# -- traces ------------------------------------------------------------------


def test_dag_arrivals_deterministic_and_validated():
    reg = default_tenants()
    a = dag_arrivals(reg, n_slices=20, seed=3, base="mmpp")
    b = dag_arrivals(reg, n_slices=20, seed=3, base="mmpp")
    assert a.arrivals == b.arrivals and a.total == b.total
    c = dag_arrivals(reg, n_slices=20, seed=4, base="mmpp")
    assert a.arrivals != c.arrivals
    for name in {t for sl in a.arrivals for t in sl}:
        assert name in reg
    with pytest.raises(ValueError, match="unknown tenant \\(in mix\\)"):
        dag_arrivals(reg, mix={"ghost": 1.0})
    with pytest.raises(ValueError, match="at least one tenant"):
        dag_arrivals(TenantRegistry())


# -- frontier property -------------------------------------------------------


def _random_dag(n, edge_bits):
    """A guaranteed-acyclic DAG on n stages: forward edges only."""
    stages = tuple(StageSpec(f"s{i}", 2) for i in range(n))
    edges, k = [], 0
    for i in range(n):
        for j in range(i + 1, n):
            if edge_bits & (1 << k):
                edges.append((f"s{i}", f"s{j}"))
            k += 1
    return DagSpec("rand", stages, tuple(edges))


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 6), st.integers(0, 2 ** 15 - 1),
       st.randoms(use_true_random=False))
def test_frontier_never_ready_before_parents_done(n, edge_bits, rnd):
    from repro.fleet.dag import DagRequest
    spec = _random_dag(n, edge_bits)
    dag = DagRequest(rid=0, tenant="t", slo_class="default", spec=spec,
                     arrival_slice=0)
    done = set()
    while not dag.done:
        ready = dag.ready_stages()
        assert ready, f"stalled with pending {dag.state}"
        for nm in ready:
            assert dag.state[nm] == PENDING
            assert all(dag.state[p] == DONE for p in spec.parents(nm))
        nm = rnd.choice(ready)           # complete one ready stage
        dag.state[nm] = DONE
        done.add(nm)
    assert done == {s.name for s in spec.stages}


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(0, 2 ** 15 - 1))
def test_random_forward_dags_topo_sort_and_budget(n, edge_bits):
    spec = _random_dag(n, edge_bits)
    order = spec.topo_order()
    pos = {nm: i for i, nm in enumerate(order)}
    for u, v in spec.edges:
        assert pos[u] < pos[v]
    assert 1 <= spec.critical_path_len() <= n


# -- end to end --------------------------------------------------------------


def test_run_dag_conserves_and_is_deterministic():
    outs = []
    for _ in range(2):
        f = _small_fleet()
        outs.append(f.run_dag(_small_trace(f)))
    a, b = outs
    tr = _small_trace(_small_fleet())
    assert (len(a.completed) + len(a.rejected)
            + len(a.unfinished)) == tr.total
    assert a.assignments == b.assignments       # determinism contract
    assert a.handoffs == b.handoffs
    assert [d.latency_ns for d in a.completed] == \
        [d.latency_ns for d in b.completed]
    for d in a.completed:
        assert d.done and d.latency_ns > 0
        assert set(d.cell_of) == {s.name for s in d.spec.stages}


def test_request_level_mode_pins_stages_and_pays_zero_handoffs():
    f = _small_fleet(stage_affinity=False)
    res = f.run_dag(_small_trace(f))
    assert res.handoffs == 0 and res.handoff_energy_pj == 0
    for d in res.completed:
        assert len(set(d.cell_of.values())) == 1


def test_dag_fleet_pays_zero_extra_lut_builds():
    subs = ["tpu-pool", "gpu-pool"]
    pc_plain = api.compiler()
    api.hierarchical_fleet(subs, n_cells=2, engines_per_cell=1,
                           compiler=pc_plain)
    pc = api.compiler()
    f = api.dag_fleet(subs, n_cells=2, engines_per_cell=1, compiler=pc,
                      seed=0)
    assert pc.n_builds == pc_plain.n_builds     # per-variant set only
    before = pc.n_builds
    f.run_dag(_small_trace(f))
    assert pc.n_builds == before                # SS.6 cache: 0 extra


def test_stage_cost_reads_lut_without_building():
    f = _small_fleet()
    sched = f.cells[0].workers[0].sched
    t1, e1 = sched.stage_cost(1)
    t4, e4 = sched.stage_cost(4)
    assert t1 > 0 and e1 > 0
    # more tasks -> tighter per-task budget -> faster, hotter placement
    assert t4 <= t1 and e4 >= e1


def test_summarize_applies_to_stage_stream_and_breakdown_sums():
    f = _small_fleet()
    res = f.run_dag(_small_trace(f, n_slices=12))
    s = summarize(res)
    assert s.n_completed == len(res.stage_result.completed) > 0
    bd = tenant_breakdown(res, f)
    assert sum(v["n_submitted"] for v in bd.values()) == \
        len(res.completed) + len(res.rejected) + len(res.unfinished)
    for name, row in bd.items():
        t = f.tenants.get(name)
        assert row["slo_class"] == t.slo_class and row["dag"] == t.dag
        assert 0.0 <= row["deadline_miss_rate"] <= 1.0


def test_budget_scales_with_critical_path_and_tenant_override():
    from repro.fleet.dag import DagRequest
    spec = DAG_SPECS["agentic"]
    dag = DagRequest(rid=0, tenant="t", slo_class="interactive",
                     spec=spec, arrival_slice=0)
    assert dag_budget_slices(dag, 3.0, Tenant("t")) == 3.0 * 4
    assert dag_budget_slices(dag, 3.0, Tenant("t", budget_slices=1.5)) == \
        1.5 * 4


def test_per_tenant_observability_and_flight_frames():
    rec = obs.FlightRecorder(capacity=64)
    obs.enable(flight_recorder=rec)
    try:
        f = _small_fleet()
        res = f.run_dag(_small_trace(f, n_slices=12))
        counters = obs.metrics().as_dict()["counters"]
        done = sum(n for k, n in counters.items()
                   if k.startswith("dag.stage.done{"))
        assert done == sum(1 for d in res.completed
                           for _ in d.spec.stages) + sum(
            1 for d in res.unfinished for s in d.state.values()
            if s == DONE)
        admission = {k: n for k, n in counters.items()
                     if k.startswith("fleet.admission{")}
        assert admission and all("tenant=" in k for k in admission)
        if res.rejected:
            assert any(REASON_TENANT_BUDGET in k for k in admission)
        assert obs.metrics().value(
            "dag.request.done", tenant=res.completed[0].tenant) > 0
        assert len(rec) > 0
        frame = rec.frames[-1]
        assert {"tenants", "cells", "running"} <= set(frame)
        json.dumps(frame)                 # frames stay JSON-serializable
    finally:
        obs.reset()


def test_background_trace_coexists_with_dags():
    f = _small_fleet()
    bg = make_trace("poisson", n_slices=10, seed=1, rate=1.0)
    res = f.run_dag(_small_trace(f), background=bg)
    assert res.background_result is not None
    n_bg = (len(res.background_result.completed)
            + len(res.background_result.rejected)
            + len(res.background_result.unfinished))
    assert n_bg == bg.total
    # stage stream stays pure StageRequest
    assert all(r.dag_rid >= 0 for r in res.stage_result.completed)


# -- CLI ---------------------------------------------------------------------


def test_cli_dag_workload_end_to_end(tmp_path):
    from repro.launch import fleet as cli
    out = tmp_path / "summary.json"
    cli.main(["--workload", "dag:mixed", "--cells", "2", "--engines", "2",
              "--steps", "8", "--json", str(out)])
    payload = json.loads(out.read_text())
    dag = payload["dag"]
    assert set(dag) >= {"n_completed", "n_rejected", "n_unfinished",
                        "handoffs", "tenants"}
    assert set(dag["tenants"]) == {"acme", "batchco", "duo"}


def test_cli_shaped_errors_for_unknown_spec_and_bad_tenants():
    from repro.launch import fleet as cli
    with pytest.raises(SystemExit, match="unknown dag spec"):
        cli.main(["--workload", "dag:nope", "--steps", "4"])
    with pytest.raises(SystemExit, match="--tenants"):
        cli.main(["--workload", "mmpp", "--tenants", "a:interactive",
                  "--steps", "4"])
