"""Launch-layer integration: lower+compile on a multi-device host mesh in a
subprocess (keeps the main test process at 1 device), plus elastic
checkpoint restore across mesh shapes."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_small_mesh_train_and_decode_compile():
    print(_run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, json
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_test_mesh
        from repro.models import lm
        from repro.optim.adamw import OptimizerConfig, make_optimizer
        from repro.parallel import sharding as sh
        from repro.train.step import make_train_step
        import dataclasses

        mesh = make_test_mesh(data=2, model=4)
        cfg = dataclasses.replace(get_smoke_config("internlm2_1_8b"),
                                  act_dp_axes=("data",))
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        psh = sh.params_shardings(params, mesh)
        params = jax.device_put(params, psh)
        opt = make_optimizer(OptimizerConfig(lr=1e-3))
        opt_state = jax.device_put(opt.init(params),
                                   sh.params_shardings_like(
                                       jax.eval_shape(opt.init, params),
                                       params, psh, mesh))
        step = make_train_step(cfg, opt, num_microbatches=2)
        B, S = 8, 32
        batch = {"tokens": jnp.zeros((B, S), jnp.int32),
                 "labels": jnp.zeros((B, S), jnp.int32)}
        bsh = sh.batch_shardings(batch, mesh)
        batch = jax.device_put(batch, bsh)
        with mesh:
            jitted = jax.jit(step, in_shardings=(psh,
                             sh.params_shardings_like(
                                 jax.eval_shape(opt.init, params), params,
                                 psh, mesh), bsh))
            p2, o2, m = jitted(params, opt_state, batch)
        assert float(m["loss"]) > 0
        # decode on the same mesh
        state = lm.init_decode_state(cfg, B, 64)
        ssh = sh.decode_state_shardings(state, mesh)
        state = jax.device_put(state, ssh)
        with mesh:
            dj = jax.jit(lambda s, t, p: lm.decode_step(params, cfg, s, t,
                                                        p),
                         in_shardings=(ssh, None, None))
            logits, state = dj(state, jnp.zeros((B,), jnp.int32),
                               jnp.int32(0))
        assert logits.shape == (B, cfg.vocab_size)
        print("MULTIDEV_OK", float(m["loss"]))
    """))


def test_elastic_checkpoint_across_mesh_shapes(tmp_path):
    """Save sharded on a 2x4 mesh, restore onto 4x2 and onto 1 device."""
    out = _run(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import ckpt
        from repro.launch.mesh import make_test_mesh
        from repro.parallel import sharding as sh
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh_a = make_test_mesh(data=2, model=4)
        w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        tree = {{"w": jax.device_put(
            w, NamedSharding(mesh_a, P("data", "model")))}}
        ckpt.save(tree, r"{tmp_path}", 3)

        mesh_b = make_test_mesh(data=4, model=2)
        out = ckpt.restore(
            {{"w": w}}, r"{tmp_path}", 3,
            shardings={{"w": NamedSharding(mesh_b, P("data", "model"))}})
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
        # and fully replicated single-device style
        out2 = ckpt.restore({{"w": w}}, r"{tmp_path}", 3)
        np.testing.assert_array_equal(np.asarray(out2["w"]), np.asarray(w))
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out
