"""Tests for the repro.fleet subsystem: traces, forecasters, the scheduler's
predicted-load hook, routing/admission, and end-to-end fleet runs."""
import numpy as np
import pytest

from repro import api
from repro.core import workloads
from repro.fleet import Fleet, make_forecaster, make_trace, summarize
from repro.fleet.forecast import AR1, EWMA, Holt, LastValue, NoForecast
from repro.fleet.router import FleetRequest
from repro.fleet.traces import TRACES, replay_trace


# -- traces ------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(TRACES))
def test_traces_seeded_deterministic_and_nonnegative(name):
    a = make_trace(name, n_slices=30, seed=3)
    b = make_trace(name, n_slices=30, seed=3)
    assert a.arrivals == b.arrivals
    assert len(a) == 30
    assert all(x >= 0 for x in a.arrivals)


def test_trace_seeds_differ():
    a = make_trace("poisson", n_slices=50, seed=0)
    b = make_trace("poisson", n_slices=50, seed=1)
    assert a.arrivals != b.arrivals


def test_flash_crowd_spikes_at_spike_slice():
    tr = make_trace("flash", n_slices=30, seed=0, spike_slice=10,
                    spike=40.0, base=1.0)
    pre = max(tr.arrivals[:10], default=0)
    assert max(tr.arrivals[10:14]) > pre


def test_workload_cases_available_as_traces():
    tr = make_trace("case3_periodic_spike")
    assert tr.arrivals == workloads.SCENARIOS["case3_periodic_spike"]


def test_trace_truncated_respects_budget():
    tr = replay_trace([5, 5, 5, 5])
    cut = tr.truncated(12)
    assert sum(cut.arrivals) == 12
    assert cut.arrivals == [5, 5, 2]


def test_make_trace_unknown_name_raises():
    with pytest.raises(ValueError):
        make_trace("nope")


# -- forecasters -------------------------------------------------------------


def test_noforecast_predicts_zero():
    f = NoForecast()
    f.observe(50)
    assert f.predict() == 0.0


def test_last_value_persistence():
    f = LastValue()
    for x in (3, 9):
        f.observe(x)
    assert f.predict() == 9.0


def test_ewma_converges_to_constant_load():
    f = EWMA(alpha=0.5)
    for _ in range(30):
        f.observe(7)
    assert f.predict() == pytest.approx(7.0)


def test_ewma_smooths_transient_dip():
    f = EWMA(alpha=0.3)
    for _ in range(10):
        f.observe(10)
    f.observe(0)                      # one-slice lull
    assert f.predict() > 5.0          # still provisioned near the burst


def test_ar1_tracks_autocorrelated_series():
    rng = np.random.default_rng(0)
    f = AR1()
    x = 5.0
    for _ in range(200):
        x = 5.0 + 0.8 * (x - 5.0) + rng.normal(0, 0.5)
        f.observe(x)
    # prediction reverts toward the mean from the last observation
    pred = f.predict()
    assert 0.0 <= pred <= 15.0
    f2 = AR1()
    for _ in range(50):
        f2.observe(4)
    assert f2.predict() == pytest.approx(4.0, abs=0.5)


def test_holt_extrapolates_ramp():
    f = Holt(alpha=0.6, beta=0.4)
    for x in range(1, 11):
        f.observe(x)
    assert f.predict() > 10.0         # trend-aware: beyond the last value


def test_make_forecaster_unknown_raises():
    with pytest.raises(ValueError):
        make_forecaster("oracle")


# -- scheduler predicted-load hook -------------------------------------------


def test_lookup_tasks_preprovisions_fast_placement():
    """Looking up a high predicted load on a quiet slice must choose a
    placement at least as fast as the reactive one."""
    f1 = api.fleet("tpu-pool", n_engines=1, forecaster="none")
    f2 = api.fleet("tpu-pool", n_engines=1, forecaster="none")
    s1 = f1.workers[0].sched
    s2 = f2.workers[0].sched
    r1 = s1.step(2)
    r2 = s2.step(2, lookup_tasks=10)
    t1 = s1.em.task_cost(r1.placement).t_task_ns
    t2 = s2.em.task_cost(r2.placement).t_task_ns
    assert t2 < t1
    # and the proactive placement can actually absorb the burst next slice
    r2b = s2.step(10)
    assert r2b.moved_weights == 0 or r2b.t_move_ns < r2.t_move_ns


def test_cap_to_capacity_limits_executed_tasks():
    fleet = api.fleet("tpu-pool", n_engines=1, forecaster="none")
    sched = fleet.workers[0].sched
    rep = sched.step(500, cap_to_capacity=True)
    assert rep.n_executed is not None
    assert rep.n_executed < 500
    assert rep.t_exec_ns + rep.t_move_ns <= sched.t_slice_ns + 1e-6
    assert not rep.deadline_met       # the full backlog would not have fit
    rep2 = sched.step(1, cap_to_capacity=True)
    assert rep2.n_executed == 1


def test_step_without_hook_unchanged():
    fleet = api.fleet("tpu-pool", n_engines=1, forecaster="none")
    sched = fleet.workers[0].sched
    rep = sched.step(5)
    assert rep.n_done == rep.n_tasks == 5
    assert rep.t_exec_ns == pytest.approx(5 * rep.t_task_ns)


# -- router / fleet ----------------------------------------------------------


def test_least_loaded_routing_balances_backlogs():
    fleet = api.fleet("tpu-pool", n_engines=2, forecaster="none",
                      policy="least_loaded")
    tr = replay_trace([10, 10])
    fleet.run(tr)
    reports = fleet.workers[0].reports, fleet.workers[1].reports
    # slice 1 executes slice 0's arrivals: 5 tasks per engine
    assert reports[0][1].n_tasks == reports[1][1].n_tasks == 5


def test_slo_routing_prefers_faster_engine_in_mixed_fleet():
    fleet = api.fleet("tpu-pool-mixed", n_engines=2, forecaster="none",
                      policy="slo")
    tr = replay_trace([8, 8, 8, 8])
    res = fleet.run(tr)
    big = sum(r.n_tasks for r in fleet.workers[0].reports)
    small = sum(r.n_tasks for r in fleet.workers[1].reports)
    assert big > small                # big engine serves the larger share
    assert len(res.completed) == 32


def test_admission_control_rejects_over_limit():
    fleet = api.fleet("tpu-pool", n_engines=1, forecaster="none",
                      admission_limit=4)
    tr = replay_trace([10, 0, 0, 0, 0, 0])
    res = fleet.run(tr)
    assert len(res.rejected) == 6     # queue cap 4 of 10 arrivals
    assert len(res.completed) == 4
    s = summarize(res)
    assert s.n_rejected == 6
    assert s.deadline_miss_rate >= 6 / 10


def test_fleet_conserves_requests_and_stamps_latency():
    tr = make_trace("mmpp", n_slices=20, seed=0)
    fleet = api.fleet("tpu-pool", n_engines=2, forecaster="ewma")
    res = fleet.run(tr)
    assert (len(res.completed) + len(res.rejected)
            + len(res.unfinished) == tr.total)
    assert not res.unfinished         # this load fully drains
    assert all(r.latency_ns is not None and r.latency_ns > 0
               for r in res.completed)
    assert all(r.finish_slice > r.arrival_slice for r in res.completed)
    s = summarize(res)
    assert s.p50_ms <= s.p95_ms <= s.p99_ms
    assert s.energy_uj > 0 and s.energy_per_token_uj > 0
    assert s.tokens == sum(r.tokens for r in res.completed)


def test_fleet_meets_slo_under_light_load():
    tr = replay_trace([2] * 15)
    fleet = api.fleet("tpu-pool", n_engines=2, forecaster="none")
    s = summarize(fleet.run(tr))
    assert s.deadline_miss_rate == 0.0
    assert s.p99_ms <= s.slo_ms


def test_unfinished_backlog_counts_as_misses():
    """Requests still queued at the drain cutoff must not vanish from the
    accounting - they count as submitted and as SLO misses."""
    fleet = api.fleet("tpu-pool", n_engines=1, forecaster="none")
    res = fleet.run(replay_trace([200]), max_drain_slices=2)
    assert res.unfinished
    s = summarize(res)
    assert s.n_submitted == 200
    assert s.n_unfinished == len(res.unfinished)
    assert s.deadline_miss_rate >= s.n_unfinished / 200


def test_seasonal_naive_predicts_one_period_back_bounded():
    from repro.fleet.forecast import SeasonalNaive
    f = SeasonalNaive(period=3)
    for x in (1, 2, 3, 4, 5, 6, 7):
        f.observe(x)
    assert f.predict() == 5.0         # the value 3 slices ago
    assert len(f._hist) == 3          # memory stays bounded at period


def test_forecasting_cuts_miss_rate_on_bursty_trace():
    """The benchmark's headline claim, pinned on a deterministic seed: a
    trend-aware forecaster beats the reactive baseline on ramping load."""
    tr = make_trace("ramp", n_slices=40, seed=1, end=12)
    reactive = summarize(
        api.fleet("tpu-pool", n_engines=1, forecaster="none").run(tr))
    proactive = summarize(
        api.fleet("tpu-pool", n_engines=1, forecaster="ewma",
                  forecast_margin=1.3).run(tr))
    assert proactive.deadline_miss_rate < reactive.deadline_miss_rate


def test_invalid_policy_and_empty_fleet_raise():
    with pytest.raises(ValueError):
        api.fleet("tpu-pool", n_engines=1, policy="fastest")
    with pytest.raises(ValueError):
        Fleet([])


def test_fleet_with_decode_exercises_tiered_weights():
    """decode=True functionally applies placements: weights are re-tiered
    and tokens decoded through the tiered model."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import lm
    cfg = get_smoke_config("internlm2_1_8b")
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    fleet = api.fleet("tpu-pool", cfg, n_engines=1, forecaster="ewma",
                      params=params, decode=True)
    tr = replay_trace([3, 2])
    res = fleet.run(tr)
    assert len(res.completed) == 5
    w = fleet.workers[0]
    assert w.hetero is not None and w.hetero._tiered is not None


# -- fleet request bookkeeping ----------------------------------------------


def test_fleet_request_defaults():
    r = FleetRequest(rid=1, arrival_slice=0)
    assert not r.rejected and r.worker is None and r.latency_ns is None
    assert r.slo_class == "default" and r.admission is None


def test_degenerate_summary_zero_completions_has_no_nans():
    """Zero completed requests must yield 0.0 stats + degenerate=True,
    never NaN (NaN breaks JSON round-trips and un-gates CI checks)."""
    import json
    import math

    fleet = api.fleet("tpu-pool", n_engines=1, forecaster="none",
                      admission_limit=0)
    s = summarize(fleet.run(replay_trace([3, 2])))
    assert s.degenerate and s.n_completed == 0
    assert s.n_rejected == 5 and s.deadline_miss_rate == 1.0
    assert (s.p50_ms, s.p95_ms, s.p99_ms, s.mean_ms) == (0.0,) * 4
    assert s.tokens == 0 and s.energy_per_token_uj == 0.0
    d = json.loads(json.dumps(s.as_dict()))
    assert not any(isinstance(v, float) and math.isnan(v)
                   for v in d.values())


def test_normal_summary_is_not_degenerate():
    fleet = api.fleet("tpu-pool", n_engines=2, forecaster="none")
    s = summarize(fleet.run(replay_trace([2] * 10)))
    assert not s.degenerate and s.n_completed > 0
