"""Tests for the hierarchical fleet (repro.fleet.hierarchy): cells,
two-level routing with per-class SLO budgets, autoscaler hysteresis,
warm-start scale-ups, and the supporting obs/registry pieces."""
import json

import pytest

from repro import api, obs
from repro.fleet import (Cell, CellRouter, HierarchicalFleet,
                         class_breakdown, make_trace, summarize)
from repro.fleet.hierarchy import REASON_BUDGET
from repro.fleet.router import ADMIT_ACCEPT, ADMIT_REJECT, FleetRequest
from repro.fleet.traces import replay_trace


def _one_cell_router(budgets=None, **kw):
    fl = api.fleet("tpu-pool", n_engines=1, forecaster="none")
    cell = Cell(0, fl.workers, tokens_per_task=2)
    router = CellRouter([cell], budgets=budgets, **kw)
    router.refresh()
    return cell, router


# -- construction contracts --------------------------------------------------


def test_empty_cell_and_empty_fleet_raise():
    with pytest.raises(ValueError):
        Cell(0, [])
    with pytest.raises(ValueError):
        HierarchicalFleet([])
    with pytest.raises(ValueError):
        api.hierarchical_fleet("tpu-pool", n_cells=1, engines_per_cell=1,
                               cell_policy="fastest")


def test_cxl_tier3_mixed_registered_and_halves_three_pools():
    assert "cxl-tier-3-mixed" in api.SUBSTRATES
    sub = api.substrate("cxl-tier-3-mixed")
    big = sub.engine_variant(0)
    small = sub.engine_variant(1)
    assert (big.n_hbm_nodes, big.n_ddr_nodes, big.n_cxl_nodes) == (2, 4, 4)
    assert (small.n_hbm_nodes, small.n_ddr_nodes,
            small.n_cxl_nodes) == (1, 2, 2)
    assert big.variant_key() != small.variant_key()


def test_hierarchical_fleet_cycles_substrates_across_cells():
    hier = api.hierarchical_fleet(["tpu-pool", "gpu-pool"], n_cells=2,
                                  engines_per_cell=1)
    names = [c.substrate.name for c in hier.cells]
    assert names == ["tpu-pool", "gpu-pool"]


# -- cell queue model --------------------------------------------------------


def test_cell_expected_wait_grows_with_backlog():
    cell, router = _one_cell_router()
    w0 = cell.expected_wait_slices(0)
    for rid in range(12):
        cell.dispatch(FleetRequest(rid=rid, arrival_slice=0))
    assert cell.backlog == 12
    assert cell.expected_wait_slices(0) > w0


def test_cell_dispatch_least_loaded_balances():
    fl = api.fleet("tpu-pool", n_engines=2, forecaster="none")
    cell = Cell(0, fl.workers, tokens_per_task=2)
    for rid in range(6):
        cell.dispatch(FleetRequest(rid=rid, arrival_slice=0))
    assert [len(w.backlog) for w in cell.workers] == [3, 3]


# -- global tier: per-class wait-based admission -----------------------------


def test_wait_based_admission_rejects_when_budget_exhausted():
    cell, router = _one_cell_router()
    rejected = None
    for rid in range(1000):
        req = FleetRequest(rid=rid, arrival_slice=0)
        if not router.route(req):
            rejected = req
            break
    assert rejected is not None, "default budget never exhausted"
    assert rejected.admission == ADMIT_REJECT and rejected.rejected
    # the expected completion latency of one more request really does
    # exceed the default budget
    assert cell.expected_latency_slices(1) > router.budget("default")


def test_batch_class_admitted_deeper_than_interactive():
    cell, router = _one_cell_router(budgets={"batch": 8.0,
                                             "interactive": 2.0})
    n_interactive = 0
    while router.route(FleetRequest(rid=n_interactive, arrival_slice=0,
                                    slo_class="interactive")):
        n_interactive += 1
        assert n_interactive < 1000
    # interactive is exhausted, but the relaxed batch budget still admits
    batch = FleetRequest(rid=9000, arrival_slice=0, slo_class="batch")
    assert router.route(batch)
    assert batch.admission == ADMIT_ACCEPT
    n_batch = n_interactive
    while router.route(FleetRequest(rid=10000 + n_batch, arrival_slice=0,
                                    slo_class="batch")):
        n_batch += 1
        assert n_batch < 5000
    assert n_batch > n_interactive    # 4x the budget -> deeper queue


def test_unknown_class_raises_shaped_error():
    _, router = _one_cell_router(budgets={"batch": 8.0})
    assert router.budget("default") == 2.0
    assert router.budget("batch") == 8.0
    with pytest.raises(ValueError, match=r"unknown SLO class 'nope'; "
                                         r"registered: \['batch', "
                                         r"'default'\]"):
        router.budget("nope")


def test_class_mix_classes_inherit_default_budget():
    fleet = api.hierarchical_fleet(
        "tpu-pool", n_cells=1, engines_per_cell=1,
        class_mix={"interactive": 0.5, "bulk": 0.5},
        budgets={"interactive": 1.5})
    assert fleet.router.budget("interactive") == 1.5
    assert fleet.router.budget("bulk") == 2.0     # inherited slo_slices


# -- determinism -------------------------------------------------------------


def test_two_level_router_deterministic_under_fixed_seed():
    kw = dict(n_cells=3, engines_per_cell=2, seed=7,
              class_mix={"interactive": 0.3, "batch": 0.2, "default": 0.5},
              budgets={"interactive": 2.0, "batch": 8.0})
    tr = make_trace("mmpp", n_slices=20, seed=3)
    res_a = api.hierarchical_fleet("tpu-pool", **kw).run(tr)
    res_b = api.hierarchical_fleet("tpu-pool", **kw).run(tr)
    assert res_a.assignments == res_b.assignments
    assert res_a.assignments, "no request was ever admitted"
    sa, sb = summarize(res_a), summarize(res_b)
    assert sa == sb


# -- autoscaler --------------------------------------------------------------


def test_autoscaler_no_flapping_on_step_trace():
    """A step load (high plateau -> low plateau) must produce one
    monotone up-phase and one monotone down-phase per cell, never an
    up/down/up oscillation (hysteresis: watermarks + patience +
    cooldown)."""
    tr = replay_trace([40] * 12 + [2] * 20)
    hier = api.hierarchical_fleet("tpu-pool", n_cells=2, engines_per_cell=2,
                                  autoscale=True, max_engines=6)
    res = hier.run(tr)
    assert res.n_scale_ups > 0 and res.n_scale_downs > 0
    for cid in range(2):
        dirs = [e.direction for e in res.scale_events if e.cell == cid]
        flips = sum(a != b for a, b in zip(dirs, dirs[1:]))
        assert flips <= 1, f"cell {cid} flapped: {dirs}"
    assert res.n_engines_peak > res.n_engines_start
    assert res.n_engines_end < res.n_engines_peak


def test_autoscaler_respects_engine_bounds():
    tr = replay_trace([60] * 10)
    hier = api.hierarchical_fleet("tpu-pool", n_cells=2, engines_per_cell=1,
                                  autoscale=True, max_engines=3)
    res = hier.run(tr)
    assert res.n_engines_peak <= 2 * 3
    for c in hier.cells:
        assert 1 <= c.n_active <= 3


def test_scale_ups_cost_zero_lut_builds_cold_and_warm(tmp_path):
    pc = api.compiler()
    tr = replay_trace([50] * 10 + [1] * 12)
    hier = api.hierarchical_fleet("tpu-pool", n_cells=2, engines_per_cell=1,
                                  autoscale=True, max_engines=4, compiler=pc)
    res = hier.run(tr)
    assert res.n_scale_ups > 0
    assert res.scale_up_builds == 0       # bring-up LUT is warm in-cache
    assert pc.n_builds == 1               # one shape, built once at bring-up
    path = tmp_path / "luts.json"
    pc.save(path)
    # warm-started process: zero builds end to end, including scale-ups
    pc2 = api.compiler()
    assert pc2.load(path) == 1
    hier2 = api.hierarchical_fleet("tpu-pool", n_cells=2,
                                   engines_per_cell=1, autoscale=True,
                                   max_engines=4, compiler=pc2)
    res2 = hier2.run(tr)
    assert pc2.n_builds == 0 and pc2.n_loaded == 1
    assert res2.scale_up_builds == 0
    # scale-downs park engines; later scale-ups reuse them without builds
    unparked = [e for e in res.scale_events
                if e.direction == "up" and e.unparked]
    for e in unparked:
        assert e.lut_builds == 0


def test_scaled_up_engine_serves_requests():
    tr = replay_trace([50] * 12)
    hier = api.hierarchical_fleet("tpu-pool", n_cells=1, engines_per_cell=1,
                                  autoscale=True, max_engines=4)
    res = hier.run(tr)
    assert res.n_scale_ups > 0
    served = {wid for _, _, wid in res.assignments}
    assert len(served) > 1                # new engines took traffic


# -- end-to-end + metrics ----------------------------------------------------


def test_hierarchy_run_conserves_requests_and_summarizes():
    tr = make_trace("mmpp", n_slices=20, seed=0)
    hier = api.hierarchical_fleet("tpu-pool", n_cells=2, engines_per_cell=2,
                                  class_mix={"interactive": 0.5,
                                             "default": 0.5},
                                  budgets={"interactive": 2.0})
    res = hier.run(tr)
    r = res.result
    assert (len(r.completed) + len(r.rejected)
            + len(r.unfinished) == tr.total)
    s = summarize(res)                    # HierarchyResult unwraps
    assert s.n_submitted == tr.total
    assert s.p50_ms <= s.p95_ms <= s.p99_ms
    assert s.energy_uj > 0
    by_class = class_breakdown(res, budgets={"interactive": 2.0})
    assert set(by_class) == {"interactive", "default"}
    assert sum(v["n_submitted"] for v in by_class.values()) == tr.total
    for v in by_class.values():
        assert 0.0 <= v["deadline_miss_rate"] <= 1.0


def test_jsq_cell_policy_end_to_end():
    tr = replay_trace([12] * 8)
    hier = api.hierarchical_fleet("tpu-pool", n_cells=2, engines_per_cell=2,
                                  cell_policy="jsq")
    s = summarize(hier.run(tr))
    assert s.n_completed == 96 and s.n_rejected == 0


def test_hierarchy_flight_frames_carry_cell_aggregates():
    obs.reset()
    rec = obs.FlightRecorder(capacity=16, miss_rate_threshold=2.0)
    obs.enable(flight_recorder=rec)
    try:
        tr = replay_trace([8] * 6)
        hier = api.hierarchical_fleet("tpu-pool", n_cells=2,
                                      engines_per_cell=1, autoscale=True,
                                      max_engines=2)
        hier.run(tr)
        assert len(rec) > 0
        frame = rec.frames[-1]
        assert {"arrivals", "admitted", "rejected", "cells",
                "scale_events", "lut_cache", "running"} <= set(frame)
        cell = frame["cells"][0]
        assert {"cell", "engines", "parked", "queue_depth",
                "expected_wait", "capacity_per_engine",
                "recent_miss_rate"} <= set(cell)
        json.dumps(frame)                 # frames stay JSON-serializable
        # the global tier counted admissions under the PR 6 schema
        # (PR 10 added the tenant label; plain requests carry "-")
        reg = obs.metrics()
        assert reg.value("fleet.admission", decision=ADMIT_ACCEPT,
                         reason="ok", cls="default", tenant="-") > 0
    finally:
        obs.reset()


def test_reject_reason_code_counted():
    obs.reset()
    obs.enable()
    try:
        cell, router = _one_cell_router()
        for rid in range(200):
            router.route(FleetRequest(rid=rid, arrival_slice=0))
        n = obs.metrics().value("fleet.admission", decision=ADMIT_REJECT,
                                reason=REASON_BUDGET, cls="default",
                                tenant="-")
        assert n > 0
    finally:
        obs.reset()


# -- obs histogram additions -------------------------------------------------


def test_histogram_quantile_nearest_rank():
    h = obs.Histogram(obs.WAIT_SLICE_BUCKETS)
    assert h.quantile(99) is None         # empty
    for x in (0, 0, 1, 1, 1, 3, 7, 100):
        h.observe(x)
    assert h.quantile(50) == 1            # bucket upper bound
    assert h.quantile(0) == 0
    assert h.quantile(100) == 100         # overflow -> observed max


def test_histogram_merge_folds_same_grid_and_rejects_other():
    a = obs.Histogram(obs.WAIT_SLICE_BUCKETS)
    b = obs.Histogram(obs.WAIT_SLICE_BUCKETS)
    for x in (0, 1, 2):
        a.observe(x)
    for x in (4, 8):
        b.observe(x)
    out = a.merge(b)
    assert out is a and a.count == 5
    assert a.quantile(100) == 8
    with pytest.raises(ValueError):
        a.merge(obs.Histogram((0, 1)))
