"""Pallas kernel validation: interpret-mode kernel vs pure-jnp oracle,
swept over shapes, block sizes and dtypes (assignment requirement).

hypothesis is an optional dependency: without it only the property-based
tests are skipped; the deterministic shape sweeps still run.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import given, settings, st

from repro.core.placement import dp_min_energy
from repro.kernels.knapsack_dp.ops import knapsack_dp
from repro.kernels.pim_mac.ops import pim_matmul
from repro.kernels.pim_mac.ref import pim_matmul_ref


# ---------------------------------------------------------------------------
# pim_mac: W8A8 matmul with fused dequant
# ---------------------------------------------------------------------------

PIM_SHAPES = [
    (8, 8, 8), (16, 32, 8), (128, 128, 128), (100, 70, 50),
    (1, 256, 64), (37, 129, 255), (256, 64, 512),
]


@pytest.mark.parametrize("M,K,N", PIM_SHAPES)
def test_pim_mac_matches_ref_across_shapes(M, K, N):
    rng = np.random.default_rng(M * 1000 + K * 10 + N)
    x = rng.integers(-128, 128, (M, K), dtype=np.int8)
    w = rng.integers(-128, 128, (K, N), dtype=np.int8)
    sx = rng.uniform(0.001, 0.2, M).astype(np.float32)
    sw = rng.uniform(0.001, 0.2, N).astype(np.float32)
    ref = pim_matmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(sx),
                         jnp.asarray(sw))
    out = pim_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(sx),
                     jnp.asarray(sw), bm=32, bn=32, bk=32,
                     backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 64, 32), (64, 16, 64),
                                      (128, 128, 128)])
def test_pim_mac_block_size_sweep(bm, bn, bk):
    rng = np.random.default_rng(bm + bn + bk)
    M, K, N = 96, 160, 80
    x = rng.integers(-128, 128, (M, K), dtype=np.int8)
    w = rng.integers(-128, 128, (K, N), dtype=np.int8)
    sx = rng.uniform(0.01, 0.1, M).astype(np.float32)
    sw = rng.uniform(0.01, 0.1, N).astype(np.float32)
    ref = pim_matmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(sx),
                         jnp.asarray(sw))
    out = pim_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(sx),
                     jnp.asarray(sw), bm=bm, bn=bn, bk=bk,
                     backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_pim_mac_output_dtypes(out_dtype):
    rng = np.random.default_rng(7)
    x = rng.integers(-128, 128, (64, 64), dtype=np.int8)
    w = rng.integers(-128, 128, (64, 64), dtype=np.int8)
    sx = rng.uniform(0.01, 0.1, 64).astype(np.float32)
    sw = rng.uniform(0.01, 0.1, 64).astype(np.float32)
    ref = pim_matmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(sx),
                         jnp.asarray(sw), out_dtype=out_dtype)
    out = pim_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(sx),
                     jnp.asarray(sw), bm=32, bn=32, bk=32,
                     out_dtype=out_dtype, backend="pallas_interpret")
    assert out.dtype == out_dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=1e-2 if out_dtype == jnp.bfloat16
                               else 1e-6)


def test_pim_mac_int32_accumulation_exact():
    """Worst-case magnitudes must not overflow/round: int32 accumulation
    over K=1024 of (+-127)^2 stays exact."""
    x = np.full((8, 1024), 127, dtype=np.int8)
    w = np.full((1024, 8), -127, dtype=np.int8)
    out = pim_matmul(jnp.asarray(x), jnp.asarray(w), jnp.float32(1.0),
                     jnp.float32(1.0), bm=8, bn=8, bk=128,
                     backend="pallas_interpret")
    assert np.all(np.asarray(out) == 127 * -127 * 1024)


@given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 40),
       st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_pim_mac_property_random_shapes(M, K, N, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (M, K), dtype=np.int8)
    w = rng.integers(-128, 128, (K, N), dtype=np.int8)
    ref = pim_matmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.float32(0.05),
                         jnp.float32(0.02))
    out = pim_matmul(jnp.asarray(x), jnp.asarray(w), jnp.float32(0.05),
                     jnp.float32(0.02), bm=16, bn=16, bk=16,
                     backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


# ---------------------------------------------------------------------------
# knapsack_dp: Algorithm-1 table kernel
# ---------------------------------------------------------------------------


def _tables_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert np.array_equal(np.isinf(a), np.isinf(b))
    np.testing.assert_allclose(a[np.isfinite(a)], b[np.isfinite(b)],
                               rtol=1e-6)


@pytest.mark.parametrize("T,K,bk", [(16, 8, 4), (40, 12, 8), (64, 33, 16),
                                    (128, 64, 64), (32, 5, 128)])
def test_knapsack_dp_kernel_vs_ref(T, K, bk):
    t_items, e_items = [2, 3], [5.0, 1.0]
    ref = knapsack_dp(t_items, e_items, T, K, backend="ref")
    pal = knapsack_dp(t_items, e_items, T, K, backend="pallas_interpret",
                      bk=bk)
    _tables_equal(ref, pal)


@given(st.lists(st.integers(1, 5), min_size=1, max_size=3), st.data())
@settings(max_examples=20, deadline=None)
def test_knapsack_dp_ref_matches_numpy(t_items, data):
    n = len(t_items)
    e_items = data.draw(st.lists(st.floats(0.5, 20.0), min_size=n,
                                 max_size=n))
    T = data.draw(st.integers(1, 24))
    K = data.draw(st.integers(1, 8))
    ref = knapsack_dp(t_items, e_items, T, K, backend="ref")
    dp_np, _ = dp_min_energy(t_items, e_items, T, K)
    _tables_equal(ref, dp_np[-1])


def test_knapsack_dp_stage_tables_match_numpy_oracle():
    """return_stages must reproduce every intermediate per-space table of
    the float64 numpy DP (the tables backtrace_tables walks)."""
    t_items, e_items = [2, 3, 1], [5.0, 1.0, 9.0]
    T, K = 48, 11
    stages = knapsack_dp(t_items, e_items, T, K, backend="ref",
                         return_stages=True)
    dp_np, _ = dp_min_energy(t_items, e_items, T, K)
    assert stages.shape == dp_np.shape == (4, T + 1, K + 1)
    for i in range(4):
        _tables_equal(stages[i], dp_np[i])
    pal = knapsack_dp(t_items, e_items, T, K, backend="pallas_interpret",
                      bk=8, return_stages=True)
    for i in range(4):
        _tables_equal(stages[i], pal[i])


def test_backtrace_tables_consistent_with_dp_objective():
    """Counts recovered from the stage tables reproduce the DP optimum
    and respect the time budget (the production dp-LUT backtrace)."""
    from repro.core.placement import backtrace_tables
    t_items, e_items = [3, 2], [7.0, 3.0]
    T, K = 30, 8
    stages = np.asarray(knapsack_dp(t_items, e_items, T, K, backend="ref",
                                    return_stages=True))
    for t in range(T + 1):
        for k in range(K + 1):
            if not np.isfinite(stages[-1][t, k]):
                continue
            x = backtrace_tables(stages, t_items, t, k)
            assert sum(x) == k
            assert sum(xi * ti for xi, ti in zip(x, t_items)) <= t
            e = sum(xi * ei for xi, ei in zip(x, e_items))
            assert e == pytest.approx(float(stages[-1][t, k]), rel=1e-6)


def test_knapsack_backend_env_override(monkeypatch):
    """backend="auto" resolves through REPRO_KNAPSACK_BACKEND, so CI can
    force the kernel (interpret) path on CPU runners where auto would
    otherwise always pick ref."""
    from repro.kernels.knapsack_dp.ops import BACKEND_ENV, resolve_backend
    monkeypatch.delenv(BACKEND_ENV, raising=False)
    assert resolve_backend("ref") == "ref"
    assert resolve_backend("auto") in ("ref", "pallas")
    monkeypatch.setenv(BACKEND_ENV, "pallas_interpret")
    assert resolve_backend("auto") == "pallas_interpret"
    assert resolve_backend("ref") == "ref"   # explicit choice wins
    out = knapsack_dp([2], [3.0], 12, 4, backend="auto", bk=4)
    _tables_equal(out, knapsack_dp([2], [3.0], 12, 4, backend="ref"))
    # a typo'd env value or explicit backend fails with the valid names,
    # not an opaque lowering error
    monkeypatch.setenv(BACKEND_ENV, "pallas-interpret")
    with pytest.raises(ValueError, match="unknown knapsack_dp backend"):
        resolve_backend("auto")
    with pytest.raises(ValueError, match="unknown knapsack_dp backend"):
        knapsack_dp([2], [3.0], 12, 4, backend="nope")


def test_dp_lut_identical_across_backends():
    """build_lut(method="dp") produces the same LUT through the ref
    backend and the Pallas kernel (interpret mode) - the dp production
    path is exercised end-to-end on CPU."""
    from repro.core import spaces as sp
    from repro.core.placement import build_lut
    from repro.core.system import default_t_slice_ns
    m = sp.EFFICIENTNET_B0
    T = default_t_slice_ns(m, 4.0)
    kw = dict(t_slice_ns=T, n_points=5, rho=4.0, method="dp",
              k_groups=24, dp_ticks=192)
    ref = build_lut(sp.hh_pim(), m, dp_backend="ref", **kw)
    pal = build_lut(sp.hh_pim(), m, dp_backend="pallas_interpret", **kw)
    assert ref.entries == pal.entries
    assert any(e.feasible for e in ref.entries)


def test_knapsack_dp_kernel_multi_space_paper_instance():
    """Run a realistically-sized HH-PIM cluster instance through the kernel
    path and compare the induced optimum against the verbatim numpy DP."""
    from repro.core import spaces as sp
    from repro.core.energy import EnergyModel
    em = EnergyModel(sp.hh_pim(), sp.EFFICIENTNET_B0, rho=4.0)
    cl = sp.hh_pim().cluster("hp")
    group = 1000
    t_items = [max(1, int(np.ceil(em.weight_time_ns(s) * group / 1e4)))
               for s in cl.spaces]
    e_items = [em.weight_energy_pj(s) * group for s in cl.spaces]
    T, K = 256, 95
    ref = knapsack_dp(t_items, e_items, T, K, backend="ref")
    pal = knapsack_dp(t_items, e_items, T, K, backend="pallas_interpret",
                      bk=32)
    dp_np, _ = dp_min_energy(t_items, e_items, T, K)
    _tables_equal(ref, pal)
    _tables_equal(ref, dp_np[-1])
