"""Pallas kernel validation: interpret-mode kernel vs pure-jnp oracle,
swept over shapes, block sizes and dtypes (assignment requirement).

hypothesis is an optional dependency: without it only the property-based
tests are skipped; the deterministic shape sweeps still run.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import given, settings, st

from repro.core.placement import dp_min_energy
from repro.kernels.knapsack_dp.ops import knapsack_dp
from repro.kernels.pim_mac.ops import pim_matmul
from repro.kernels.pim_mac.ref import pim_matmul_ref


# ---------------------------------------------------------------------------
# pim_mac: W8A8 matmul with fused dequant
# ---------------------------------------------------------------------------

PIM_SHAPES = [
    (8, 8, 8), (16, 32, 8), (128, 128, 128), (100, 70, 50),
    (1, 256, 64), (37, 129, 255), (256, 64, 512),
]


@pytest.mark.parametrize("M,K,N", PIM_SHAPES)
def test_pim_mac_matches_ref_across_shapes(M, K, N):
    rng = np.random.default_rng(M * 1000 + K * 10 + N)
    x = rng.integers(-128, 128, (M, K), dtype=np.int8)
    w = rng.integers(-128, 128, (K, N), dtype=np.int8)
    sx = rng.uniform(0.001, 0.2, M).astype(np.float32)
    sw = rng.uniform(0.001, 0.2, N).astype(np.float32)
    ref = pim_matmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(sx),
                         jnp.asarray(sw))
    out = pim_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(sx),
                     jnp.asarray(sw), bm=32, bn=32, bk=32,
                     backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 64, 32), (64, 16, 64),
                                      (128, 128, 128)])
def test_pim_mac_block_size_sweep(bm, bn, bk):
    rng = np.random.default_rng(bm + bn + bk)
    M, K, N = 96, 160, 80
    x = rng.integers(-128, 128, (M, K), dtype=np.int8)
    w = rng.integers(-128, 128, (K, N), dtype=np.int8)
    sx = rng.uniform(0.01, 0.1, M).astype(np.float32)
    sw = rng.uniform(0.01, 0.1, N).astype(np.float32)
    ref = pim_matmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(sx),
                         jnp.asarray(sw))
    out = pim_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(sx),
                     jnp.asarray(sw), bm=bm, bn=bn, bk=bk,
                     backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_pim_mac_output_dtypes(out_dtype):
    rng = np.random.default_rng(7)
    x = rng.integers(-128, 128, (64, 64), dtype=np.int8)
    w = rng.integers(-128, 128, (64, 64), dtype=np.int8)
    sx = rng.uniform(0.01, 0.1, 64).astype(np.float32)
    sw = rng.uniform(0.01, 0.1, 64).astype(np.float32)
    ref = pim_matmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(sx),
                         jnp.asarray(sw), out_dtype=out_dtype)
    out = pim_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(sx),
                     jnp.asarray(sw), bm=32, bn=32, bk=32,
                     out_dtype=out_dtype, backend="pallas_interpret")
    assert out.dtype == out_dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=1e-2 if out_dtype == jnp.bfloat16
                               else 1e-6)


def test_pim_mac_int32_accumulation_exact():
    """Worst-case magnitudes must not overflow/round: int32 accumulation
    over K=1024 of (+-127)^2 stays exact."""
    M = K = N = 0
    x = np.full((8, 1024), 127, dtype=np.int8)
    w = np.full((1024, 8), -127, dtype=np.int8)
    out = pim_matmul(jnp.asarray(x), jnp.asarray(w), jnp.float32(1.0),
                     jnp.float32(1.0), bm=8, bn=8, bk=128,
                     backend="pallas_interpret")
    assert np.all(np.asarray(out) == 127 * -127 * 1024)


@given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 40),
       st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_pim_mac_property_random_shapes(M, K, N, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (M, K), dtype=np.int8)
    w = rng.integers(-128, 128, (K, N), dtype=np.int8)
    ref = pim_matmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.float32(0.05),
                         jnp.float32(0.02))
    out = pim_matmul(jnp.asarray(x), jnp.asarray(w), jnp.float32(0.05),
                     jnp.float32(0.02), bm=16, bn=16, bk=16,
                     backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


# ---------------------------------------------------------------------------
# knapsack_dp: Algorithm-1 table kernel
# ---------------------------------------------------------------------------


def _tables_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert np.array_equal(np.isinf(a), np.isinf(b))
    np.testing.assert_allclose(a[np.isfinite(a)], b[np.isfinite(b)],
                               rtol=1e-6)


@pytest.mark.parametrize("T,K,bk", [(16, 8, 4), (40, 12, 8), (64, 33, 16),
                                    (128, 64, 64), (32, 5, 128)])
def test_knapsack_dp_kernel_vs_ref(T, K, bk):
    t_items, e_items = [2, 3], [5.0, 1.0]
    ref = knapsack_dp(t_items, e_items, T, K, backend="ref")
    pal = knapsack_dp(t_items, e_items, T, K, backend="pallas_interpret",
                      bk=bk)
    _tables_equal(ref, pal)


@given(st.lists(st.integers(1, 5), min_size=1, max_size=3), st.data())
@settings(max_examples=20, deadline=None)
def test_knapsack_dp_ref_matches_numpy(t_items, data):
    n = len(t_items)
    e_items = data.draw(st.lists(st.floats(0.5, 20.0), min_size=n,
                                 max_size=n))
    T = data.draw(st.integers(1, 24))
    K = data.draw(st.integers(1, 8))
    ref = knapsack_dp(t_items, e_items, T, K, backend="ref")
    dp_np, _ = dp_min_energy(t_items, e_items, T, K)
    _tables_equal(ref, dp_np[-1])


def test_knapsack_dp_kernel_multi_space_paper_instance():
    """Run a realistically-sized HH-PIM cluster instance through the kernel
    path and compare the induced optimum against the verbatim numpy DP."""
    from repro.core import spaces as sp
    from repro.core.energy import EnergyModel
    em = EnergyModel(sp.hh_pim(), sp.EFFICIENTNET_B0, rho=4.0)
    cl = sp.hh_pim().cluster("hp")
    group = 1000
    t_items = [max(1, int(np.ceil(em.weight_time_ns(s) * group / 1e4)))
               for s in cl.spaces]
    e_items = [em.weight_energy_pj(s) * group for s in cl.spaces]
    T, K = 256, 95
    ref = knapsack_dp(t_items, e_items, T, K, backend="ref")
    pal = knapsack_dp(t_items, e_items, T, K, backend="pallas_interpret",
                      bk=32)
    dp_np, _ = dp_min_energy(t_items, e_items, T, K)
    _tables_equal(ref, pal)
    _tables_equal(ref, dp_np[-1])
