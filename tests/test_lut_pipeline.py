"""Fused LUT-pipeline validation (repro.kernels.lut_pipeline).

Three layers of evidence that the fused op never changes a byte:

  * the jax min-plus fold (``multipool.minplus_fold_jnp`` /
    ``combine_rows_jnp``) against the numpy host fold - bitwise values,
    identical first-minimum argmin splits, plus hypothesis property
    tests (fold associativity on integer-valued tables, feasible-split
    reconstruction, a K=3 brute-force oracle);
  * the fused op across backends (``ref`` vs ``pallas_interpret``,
    multi-panel carry chains included) against the unfused
    ``knapsack_dp`` + ``combine_many`` reference;
  * whole dp LUT builds: fused-batched vs per-point host loop vs the
    clock-grid batched driver, entry-for-entry equality.

hypothesis is an optional dependency: without it only the property
tests skip; the deterministic sweeps still run.
"""
import numpy as np
import pytest

from conftest import given, settings, st

from repro import api
from repro.core.multipool import combine_many, combine_rows_jnp
from repro.kernels.knapsack_dp.ops import knapsack_dp
from repro.kernels.lut_pipeline.ops import (BACKEND_ENV, lut_build,
                                            resolve_backend)

BACKENDS = ("ref", "pallas_interpret")


def _rand_problem(seed, *, V=1, C=2, n=2, T=24, K=4, R=6):
    rng = np.random.default_rng(seed)
    t_items = rng.integers(1, max(2, T // 3), size=(V, C, n))
    e_items = rng.integers(1, 40, size=(V, C, n)).astype(np.float32)
    rows = rng.integers(0, T + 1, size=(V, R))
    return t_items, e_items, rows


def _unfused(t_items, e_items, T, K, rows):
    """Reference: per-cluster knapsack op + host numpy fold."""
    V, C, n = t_items.shape
    stages, min_e, splits = [], [], []
    for v in range(V):
        finals, stages_v = [], []
        for c in range(C):
            st_c = np.asarray(knapsack_dp(
                list(t_items[v, c]), list(e_items[v, c]), T, K,
                backend="ref", return_stages=True))
            stages_v.append(st_c)
            finals.append(st_c[-1][rows[v]])
        m_e, sp = combine_many(finals)
        stages.append(np.stack(stages_v))
        min_e.append(m_e)
        splits.append(sp)
    return np.stack(stages), np.stack(min_e), np.stack(splits)


# ---------------------------------------------------------------------------
# jax fold vs numpy fold
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("C,R,K", [(1, 4, 5), (2, 6, 4), (3, 5, 3),
                                   (5, 3, 4)])
def test_combine_rows_jnp_matches_numpy_fold(C, R, K):
    rng = np.random.default_rng(C * 100 + R * 10 + K)
    tables = rng.integers(0, 50, size=(C, R, K + 1)).astype(np.float32)
    tables[rng.random(tables.shape) < 0.3] = np.inf
    min_e, splits = combine_rows_jnp(np.asarray(tables))
    ref_e, ref_s = combine_many(list(tables))
    assert np.array_equal(np.asarray(min_e), ref_e, equal_nan=True)
    assert np.array_equal(np.asarray(splits), ref_s)


def test_combine_many_shaped_validation_errors():
    """Mismatched tables fail with the offending cluster index and both
    shapes, not a broadcast error deep inside the fold."""
    good = np.zeros((3, 4), np.float32)
    with pytest.raises(ValueError, match="at least one cluster table"):
        combine_many([])
    with pytest.raises(ValueError, match=r"cluster 0: table must be 2-D"):
        combine_many([np.zeros(4, np.float32)])
    with pytest.raises(ValueError, match=r"cluster 1: table shape \(2, 4\)"):
        combine_many([good, np.zeros((2, 4), np.float32)])
    with pytest.raises(ValueError, match=r"cluster 2: table shape"):
        combine_many([good, good, np.zeros((3, 5), np.float32)])


def test_combine_rows_jnp_first_minimum_tie_breaking():
    # two optimal splits: the numpy fold takes the first minimum; the
    # jax fold must pick the same one
    t = np.zeros((2, 1, 4), np.float32)     # every split costs 0
    min_e, splits = combine_rows_jnp(np.asarray(t))
    ref_e, ref_s = combine_many(list(t))
    assert np.array_equal(np.asarray(splits), ref_s)
    assert np.array_equal(np.asarray(min_e), ref_e)


@settings(deadline=None, max_examples=30)
@given(st.integers(0, 10_000), st.integers(2, 4), st.integers(2, 6),
       st.integers(1, 5))
def test_fold_associativity_property(seed, C, R, K):
    """Folding C integer-valued tables is associative: left fold ==
    fold of (first two) then the rest. Integer-valued float32 sums stay
    exact, so equality is bitwise."""
    rng = np.random.default_rng(seed)
    tables = rng.integers(0, 30, size=(C, R, K + 1)).astype(np.float32)
    tables[rng.random(tables.shape) < 0.25] = np.inf
    left_e, _ = combine_many(list(tables))
    if C > 2:
        from repro.core.multipool import minplus_fold
        head, _ = minplus_fold(tables[0], tables[1])
        re_e, _ = combine_many([head] + list(tables[2:]))
        assert np.array_equal(left_e, re_e, equal_nan=True)
    jnp_e, _ = combine_rows_jnp(np.asarray(tables))
    assert np.array_equal(np.asarray(jnp_e), left_e, equal_nan=True)


@settings(deadline=None, max_examples=30)
@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(1, 6),
       st.integers(2, 7))
def test_backtrace_reconstructs_feasible_split_property(seed, C, R, K):
    """On every feasible row the argmin backtrace must name a split that
    (a) sums to K and (b) reproduces min_e when priced against the
    tables."""
    rng = np.random.default_rng(seed)
    tables = rng.integers(0, 25, size=(C, R, K + 1)).astype(np.float32)
    tables[rng.random(tables.shape) < 0.3] = np.inf
    min_e, splits = map(np.asarray, combine_rows_jnp(np.asarray(tables)))
    for r in range(R):
        if not np.isfinite(min_e[r]):
            assert (splits[r] == -1).all()
            continue
        assert splits[r].sum() == K
        priced = sum(tables[c, r, splits[r][c]] for c in range(C))
        assert priced == min_e[r]


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 10_000), st.integers(1, 12))
def test_k3_brute_force_oracle_property(seed, K):
    """C=3 fold vs brute force over all (i, j, K-i-j) splits (<=12
    weights)."""
    rng = np.random.default_rng(seed)
    R = 3
    tables = rng.integers(0, 40, size=(3, R, K + 1)).astype(np.float32)
    tables[rng.random(tables.shape) < 0.2] = np.inf
    min_e, splits = map(np.asarray, combine_rows_jnp(np.asarray(tables)))
    for r in range(R):
        best = np.inf
        for i in range(K + 1):
            for j in range(K + 1 - i):
                best = min(best, tables[0, r, i] + tables[1, r, j]
                           + tables[2, r, K - i - j])
        if np.isfinite(best):
            assert min_e[r] == best
            i, j, k = splits[r]
            assert tables[0, r, i] + tables[1, r, j] + tables[2, r, k] \
                == best
        else:
            assert not np.isfinite(min_e[r])


# ---------------------------------------------------------------------------
# fused op vs the unfused knapsack + combine_many reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("V,C,n,T,K,R,bk", [
    (1, 2, 2, 24, 4, 6, 512),       # the edge/pool topology
    (2, 3, 1, 30, 5, 7, 512),       # cxl-tier-3-like, variant-batched
    (1, 2, 3, 40, 7, 5, 4),         # multi-panel carry chain (P=2)
    (3, 1, 2, 16, 3, 4, 512),       # single cluster (no fold)
    (2, 5, 1, 32, 6, 9, 8),         # deep fold, multi-panel
])
def test_fused_op_matches_unfused_reference(backend, V, C, n, T, K, R, bk):
    t_items, e_items, rows = _rand_problem(
        V * 7919 + C * 31 + n, V=V, C=C, n=n, T=T, K=K, R=R)
    # exercise the inert-padding contract on one space
    e_items[0, C - 1, n - 1] = np.inf
    t_items[0, C - 1, n - 1] = 1
    stages, min_e, splits = map(np.asarray, lut_build(
        t_items, e_items, T, K, rows, backend=backend, bk=bk))
    ref_stages, ref_e, ref_s = _unfused(t_items, e_items, T, K, rows)
    assert np.array_equal(stages, ref_stages), "stage tables drifted"
    assert np.array_equal(min_e, ref_e, equal_nan=True)
    assert np.array_equal(splits, ref_s)


def test_fused_op_backends_bitwise_identical():
    t_items, e_items, rows = _rand_problem(5, V=2, C=3, n=2, T=28, K=5, R=8)
    out = {b: tuple(map(np.asarray,
                        lut_build(t_items, e_items, 28, 5, rows,
                                  backend=b, bk=4)))
           for b in BACKENDS}
    for a, b in zip(out["ref"], out["pallas_interpret"]):
        assert np.array_equal(a, b, equal_nan=True)


def test_rows_broadcast_and_validation():
    t_items, e_items, rows = _rand_problem(9, V=2)
    # 1-D rows broadcast across variants
    s1, e1, p1 = map(np.asarray, lut_build(t_items, e_items, 24, 4,
                                           rows[0], backend="ref"))
    s2, e2, p2 = map(np.asarray, lut_build(
        t_items, e_items, 24, 4, np.stack([rows[0], rows[0]]),
        backend="ref"))
    assert np.array_equal(e1, e2, equal_nan=True)
    with pytest.raises(ValueError, match=r"\(V, C, n\)"):
        lut_build(t_items[0], e_items[0], 24, 4, rows[0], backend="ref")


def test_backend_env_override_and_validation(monkeypatch):
    monkeypatch.delenv(BACKEND_ENV, raising=False)
    assert resolve_backend("ref") == "ref"
    assert resolve_backend("auto") in ("ref", "pallas")
    monkeypatch.setenv(BACKEND_ENV, "pallas_interpret")
    assert resolve_backend("auto") == "pallas_interpret"
    assert resolve_backend("ref") == "ref"   # explicit beats env
    monkeypatch.setenv(BACKEND_ENV, "pallas_interpet")   # typo
    with pytest.raises(ValueError, match="unknown lut_pipeline backend"):
        resolve_backend("auto")


# ---------------------------------------------------------------------------
# whole-LUT equivalence: fused build vs per-point host fold
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_dp_lut_fused_matches_per_point_host_fold(backend):
    """build_lut(method="dp") through the fused op (either backend) is
    entry-for-entry identical to the unfused per-point host loop
    (batched=False: one knapsack_dp per cluster + numpy combine per
    grid point) - the byte-identity anchor of the whole pipeline."""
    from repro.core import spaces as csp
    from repro.core.placement import build_lut
    from repro.core.system import default_t_slice_ns
    m = csp.EFFICIENTNET_B0
    T = default_t_slice_ns(m, 4.0)
    kw = dict(t_slice_ns=T, n_points=5, rho=4.0, method="dp",
              k_groups=24, dp_ticks=192)
    fused = build_lut(csp.hh_pim(), m, lut_backend=backend, **kw)
    loop = build_lut(csp.hh_pim(), m, batched=False, **kw)
    assert fused.entries == loop.entries
    assert any(e.feasible for e in fused.entries)
    assert fused.backend == backend and loop.backend is None


@pytest.mark.parametrize("backend", BACKENDS)
def test_dp_lut_fused_three_pool(backend):
    """Same identity on the K=3-pool substrate (the C>2 fold with the
    argmin-trace backtrace actually engaged)."""
    from repro.core.placement import build_lut
    sub = api.substrate("cxl-tier-3")
    em = sub.energy_model()
    T = sub.default_t_slice_ns()
    kw = dict(t_slice_ns=T, n_points=4, method="dp", k_groups=16,
              dp_ticks=128, em=em, static_window=sub.static_window)
    fused = build_lut(sub.arch, em.model, lut_backend=backend, **kw)
    loop = build_lut(sub.arch, em.model, batched=False, **kw)
    assert fused.entries == loop.entries
    assert any(e.feasible for e in fused.entries)


def test_clock_grid_build_matches_per_variant_builds():
    """build_lut_grid stacks DVFS clock variants on the fused op's
    variant axis; every returned LUT must be byte-identical to its own
    single-variant build."""
    from repro.core.placement import build_lut, build_lut_grid
    sub = api.substrate("cxl-tier-3")
    T = sub.default_t_slice_ns()
    clocks = sub.tech_model().clock_grid(3)
    ems = [sub.with_clock(c).energy_model() for c in clocks]
    kw = dict(t_slice_ns=T, n_points=4, k_groups=16, dp_ticks=128,
              method="dp", static_window=sub.static_window)
    grid = build_lut_grid(ems, **kw)
    assert len(grid) == len(clocks)
    for em, lut in zip(ems, grid):
        single = build_lut(em.arch, em.model, em=em, **kw)
        assert lut.entries == single.entries
        assert lut.backend == single.backend


def test_compiler_clock_grid_uses_one_fused_launch():
    """compile_clock_grid with a batched dp solver solves all missing
    clock points in one fused launch and attributes every build to the
    resolved lut_pipeline backend."""
    pc = api.compiler()
    sub = api.substrate("cxl-tier-3", solver="dp", lut_points=4)
    luts = pc.compile_clock_grid(sub, n_clocks=3, n_points=4)
    n = len(luts)
    assert n >= 3
    stats = pc.stats()
    assert stats["builds"] == n
    backend = resolve_backend("auto")
    assert stats["builds_by_backend"] == {backend: n}
    # same grid again: all hits, no new builds
    again = pc.compile_clock_grid(sub, n_clocks=3, n_points=4)
    assert pc.stats()["builds"] == n and pc.stats()["hits"] == n
    for c, lut in luts.items():
        assert again[c] is lut
