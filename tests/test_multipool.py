"""K-pool placement tests (repro.core.multipool + cxl-tier-3).

Three layers of evidence that the min-plus multi-cluster combine is
right:

  * an exhaustive brute-force oracle at small K/grid (K=3 clusters,
    <= 12 weights) agreeing with ``combine_many`` on both the energy
    and the backtraced split,
  * an exact reduction proof for C == 2: ``combine_many`` reproduces
    the pairwise Algorithm-2 scan bit-for-bit,
  * a golden-digest regression: every substrate registered before the
    refactor builds byte-identical LUTs through the new combine (the
    digests below were captured from the pre-refactor tree).

Plus the end-to-end exercise: the three-pool ``cxl-tier-3`` substrate
builds LUTs via both solver methods, agrees dp-vs-closed-form, and runs
a fleet slice.
"""
import hashlib
import itertools
import json

import numpy as np
import pytest

from repro import api
from repro.core import workloads
from repro.core.energy import validate_placement
from repro.core.multipool import combine_many, minplus_fold
from repro.core.placement import (ClosedFormSolver, build_lut,
                                  combine_clusters, dp_min_energy)


# ---------------------------------------------------------------------------
# combine_many vs exhaustive brute force (K=3 clusters, <= 12 weights)
# ---------------------------------------------------------------------------


def _random_cluster_tables(rng, C, K, T):
    """Per-cluster final DP tables from the float64 oracle."""
    tabs = []
    for _ in range(C):
        n = int(rng.integers(1, 3))
        t_it = rng.integers(1, 6, n).tolist()
        e_it = rng.uniform(0.1, 20.0, n).tolist()
        dp, _ = dp_min_energy(t_it, e_it, T, K)
        tabs.append(dp[n])
    return tabs


def test_combine_many_matches_bruteforce_k3():
    rng = np.random.default_rng(7)
    for trial in range(120):
        C, K = 3, int(rng.integers(1, 13))
        T = int(rng.integers(0, 22))
        tabs = _random_cluster_tables(rng, C, K, T)
        min_e, splits = combine_many(tabs)
        for t in (0, T // 2, T):
            best = float("inf")
            for ks in itertools.product(range(K + 1), repeat=C):
                if sum(ks) != K:
                    continue
                best = min(best, sum(tabs[c][t, ks[c]] for c in range(C)))
            got = min_e[t]
            if np.isinf(best):
                assert np.isinf(got)
                assert (splits[t] == -1).all()
            else:
                assert got == pytest.approx(best, rel=1e-12)
                s = splits[t]
                assert int(s.sum()) == K and (s >= 0).all()
                # the split recomposes exactly the reported optimum
                recomposed = sum(tabs[c][t, s[c]] for c in range(C))
                assert recomposed == got


def test_combine_many_deep_fold_k5():
    """Several intermediate folds (C=5) still match brute force."""
    rng = np.random.default_rng(3)
    for trial in range(20):
        C, K = 5, int(rng.integers(1, 7))
        T = int(rng.integers(1, 15))
        tabs = _random_cluster_tables(rng, C, K, T)
        min_e, splits = combine_many(tabs)
        best = float("inf")
        for ks in itertools.product(range(K + 1), repeat=C):
            if sum(ks) != K:
                continue
            best = min(best, sum(tabs[c][T, ks[c]] for c in range(C)))
        if np.isinf(best):
            assert np.isinf(min_e[T])
        else:
            assert min_e[T] == pytest.approx(best, rel=1e-12)
            assert int(splits[T].sum()) == K


def test_combine_many_two_tables_is_pairwise_algorithm2():
    """C == 2 degenerates to exactly the historic pairwise scan: same
    additions, same first-minimum argmin, bit-for-bit."""
    rng = np.random.default_rng(11)
    for trial in range(40):
        K = int(rng.integers(0, 9))
        T = int(rng.integers(0, 25))
        a, b = _random_cluster_tables(rng, 2, K, T)
        min_e, splits = combine_many([a, b])
        # the pre-refactor pairwise formula, verbatim
        total = a + b[:, ::-1]
        k_opt = np.argmin(total, axis=1)
        ref_e = total[np.arange(T + 1), k_opt]
        ref_k = np.where(np.isinf(ref_e), -1, k_opt)
        np.testing.assert_array_equal(min_e, ref_e)
        np.testing.assert_array_equal(splits[:, 0], ref_k)
        feas = np.isfinite(ref_e)
        np.testing.assert_array_equal(splits[feas, 1], K - ref_k[feas])
        # and the named Algorithm-2 API delegates to the same fold
        ce, ck = combine_clusters(a, b)
        np.testing.assert_array_equal(ce, ref_e)
        np.testing.assert_array_equal(ck, ref_k)


def test_combine_many_single_cluster():
    dp, _ = dp_min_energy([2], [3.0], 10, 4)
    min_e, splits = combine_many([dp[1]])
    assert np.isinf(min_e[7])                # 4 items need t >= 8
    assert (splits[7] == -1).all()
    assert min_e[8] == pytest.approx(12.0)
    assert splits[8].tolist() == [4]


def test_minplus_fold_properties():
    rng = np.random.default_rng(5)
    a, b = _random_cluster_tables(rng, 2, 6, 12)
    out, arg = minplus_fold(a, b)
    R, K1 = a.shape
    for r in range(0, R, 3):
        for k in range(K1):
            want = min(a[r, i] + b[r, k - i] for i in range(k + 1))
            if np.isinf(want):
                assert np.isinf(out[r, k])
            else:
                assert out[r, k] == want
                i = arg[r, k]
                assert a[r, i] + b[r, k - i] == out[r, k]


# ---------------------------------------------------------------------------
# Byte-identity regression: pre-refactor substrates, golden digests
# ---------------------------------------------------------------------------

# Captured from the seed tree (pre-multipool pairwise combine) with the
# exact build parameters below: every pre-existing 1-/2-cluster
# substrate must keep producing these bytes through the K-pool fold.
GOLDEN_LUT_DIGESTS = {
    "cxl-tier:closed_form": "3653af7c0d0569cb",
    "cxl-tier:dp": "549a9fef6ae223b4",
    "edge-baseline:closed_form": "f76a5f3c6ead009a",
    "edge-baseline:dp": "f76a5f3c6ead009a",
    "edge-hetero:closed_form": "cda0ae1977f42590",
    "edge-hetero:dp": "cda0ae1977f42590",
    "edge-hhpim:closed_form": "c44f42c135341f75",
    "edge-hhpim:dp": "c44f42c135341f75",
    "edge-hybrid:closed_form": "02f9711c2b0627e2",
    "edge-hybrid:dp": "847c8c5fc106581b",
    "gpu-pool:closed_form": "5bbccc0162bc4de2",
    "gpu-pool:dp": "5bbccc0162bc4de2",
    "gpu-pool-mixed:closed_form": "5bbccc0162bc4de2",
    "gpu-pool-mixed:dp": "5bbccc0162bc4de2",
    "tpu-pool:closed_form": "90c5bdf20b5fec46",
    "tpu-pool:dp": "abee1aab40e12410",
    "tpu-pool-mixed:closed_form": "90c5bdf20b5fec46",
    "tpu-pool-mixed:dp": "abee1aab40e12410",
}


def lut_digest(lut):
    """Canonical bit-exact digest of a LUT (float bytes via hex)."""
    payload = []
    for e in lut.entries:
        payload.append([e.t_constraint_ns.hex(),
                        sorted((k, int(v)) for k, v in e.placement.items()),
                        float(e.e_task_pj).hex(), float(e.t_task_ns).hex(),
                        bool(e.feasible)])
    blob = json.dumps([lut.arch_name, lut.model_name, payload],
                      sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


@pytest.mark.parametrize("key", sorted(GOLDEN_LUT_DIGESTS))
def test_preexisting_substrate_luts_unchanged_by_kpool_refactor(key):
    name, method = key.split(":")
    sub = api.substrate(name)
    model = sub.model_spec()
    T = sub.default_t_slice_ns(model)
    em = sub.energy_model(model)
    lut = build_lut(sub.arch, model, t_slice_ns=T, n_points=6,
                    k_groups=64, em=em, method=method,
                    static_window=sub.static_window)
    assert lut_digest(lut) == GOLDEN_LUT_DIGESTS[key], key


# ---------------------------------------------------------------------------
# cxl-tier-3: the three-pool substrate end-to-end
# ---------------------------------------------------------------------------


def test_cxl_tier_3_registered():
    # ISSUE acceptance: picked up by substrate-smoke via the registry
    assert "cxl-tier-3" in api.list_substrates()
    sub = api.substrate("cxl-tier-3")
    assert len(sub.arch.clusters) == 3
    assert [c.name for c in sub.arch.clusters] == ["hbm", "ddr", "cxl"]


@pytest.mark.parametrize("solver", ["closed-form", "dp"])
def test_cxl_tier_3_builds_valid_luts_both_solvers(solver):
    sub = api.substrate("cxl-tier-3", tokens_per_task=2)
    model = sub.model_spec()
    T = sub.default_t_slice_ns(model)
    lut = sub.build_lut(model, t_slice_ns=T, n_points=8, solver=solver)
    feas = [e for e in lut.entries if e.feasible]
    assert feas, solver
    em = sub.energy_model(model)
    for e in feas:
        validate_placement(sub.arch, model, e.placement)
        assert em.task_cost(e.placement).t_task_ns <= e.t_constraint_ns + 1e-6
    # tight constraint engages all three pools; relaxed parks everything
    # in the far (CXL) tier, whose idle cost is retention power-down
    assert sum(v > 0 for v in feas[0].placement.values()) == 3
    assert feas[-1].placement.get("cxl_mram", 0) == model.n_params


def test_cxl_tier_3_dp_and_closed_form_agree():
    sub = api.substrate("cxl-tier-3", tokens_per_task=2)
    model = sub.model_spec()
    T = sub.default_t_slice_ns(model)
    loads = workloads.SCENARIOS["case6_random"]
    res = {}
    for solver in ("closed-form", "dp"):
        sched = api.scheduler(sub, model, t_slice_ns=T, lut_points=16,
                              solver=solver)
        reports = sched.run(loads)
        res[solver] = (sum(r.energy_pj for r in reports),
                       sum(not r.deadline_met for r in reports))
    cf, dp = res["closed-form"], res["dp"]
    assert dp[1] == cf[1]
    assert dp[0] == pytest.approx(cf[0], rel=0.10)


def test_cxl_tier_3_closed_form_matches_simplex_bruteforce():
    """The K-pool closed-form optimum equals exhaustive search over the
    per-cluster split simplex (small group grid, full energy model)."""
    sub = api.substrate("cxl-tier-3", tokens_per_task=2)
    model = sub.model_spec()
    em = sub.energy_model(model)
    t_peak = em.task_cost(em.peak_placement(True)).t_task_ns
    Kg = 12
    group = -(-model.n_params // Kg)
    solver = ClosedFormSolver(em, group=group)
    for frac in (0.9, 0.4, 0.1):
        t_budget = t_peak / frac
        sols = [solver.solve_cluster(c, Kg, t_budget, t_budget)
                for c in sub.arch.clusters]
        min_e, splits = combine_many([s.energy_pj[None, :] for s in sols])
        brute = min(
            (sum(sols[c].energy_pj[ks[c]] for c in range(3))
             for ks in itertools.product(range(Kg + 1), repeat=3)
             if sum(ks) == Kg), default=float("inf"))
        assert min_e[0] == pytest.approx(brute, rel=1e-12)
        assert int(splits[0].sum()) == Kg


def test_cxl_tier_3_peak_placement_spans_all_pools():
    sub = api.substrate("cxl-tier-3", tokens_per_task=2)
    model = sub.model_spec()
    em = sub.energy_model(model)
    pl = em.peak_placement(sram_only=True)
    assert sum(pl.values()) == model.n_params
    assert set(pl) == {"hbm_sram", "ddr_sram", "cxl_mram"}
    assert all(v > 0 for v in pl.values())
    # balanced makespan: faster pools take proportionally more weights
    em_cost = em.task_cost(pl)
    busy = list(em_cost.t_cluster_ns.values())
    assert max(busy) <= min(b for b in busy if b > 0) * 1.10


def test_far_only_cluster_closed_form_matches_manual():
    """The far-tier-only branch of ClosedFormSolver (single non-volatile
    space) reproduces the hand-computed linear cost."""
    sub = api.substrate("cxl-tier-3", tokens_per_task=2)
    model = sub.model_spec()
    em = sub.energy_model(model)
    cxl = sub.arch.cluster("cxl")
    solver = ClosedFormSolver(em, group=1)
    K = 16
    tw = em.weight_time_ns(cxl.spaces[0])
    budget = 10.5 * tw                      # k <= 10 feasible
    sol = solver.solve_cluster(cxl, K, budget, budget)
    for k in range(K + 1):
        busy = k * tw
        if k <= 10:
            want = (k * em.weight_energy_pj(cxl.spaces[0])
                    + (cxl.spaces[0].static_mw_total
                       + cxl.pe_static_mw_total) * busy if k else 0.0)
            assert sol.energy_pj[k] == pytest.approx(want, rel=1e-12)
            assert sol.x_mram[k] == k
            assert sol.busy_ns[k] == pytest.approx(busy)
        else:
            assert np.isinf(sol.energy_pj[k])
    # batched rows are bit-identical to the per-point solve
    batch = solver.solve_clusters(cxl, K, [budget, 2 * budget],
                                  [budget, 2 * budget])
    np.testing.assert_array_equal(batch.energy_pj[0], sol.energy_pj)
    np.testing.assert_array_equal(batch.x_mram[0], sol.x_mram)


def test_cxl_tier_3_fleet_slice_and_mixed_shaping():
    from repro.fleet import summarize
    from repro.fleet.traces import replay_trace
    pc = api.compiler()
    fl = api.fleet("cxl-tier-3", n_engines=2, forecaster="none",
                   compiler=pc)
    s = summarize(fl.run(replay_trace([4, 2, 4])))
    assert s.n_completed == 10
    assert s.energy_uj > 0
    assert pc.stats()["builds"] == 1        # one shape -> one build
    # mixed shaping halves every one of the THREE pools
    sub = api.substrate("cxl-tier-3", mixed=True)
    small = sub.engine_variant(1)
    assert small._pool_counts() == tuple(max(c // 2, 1)
                                         for c in sub._pool_counts())
    assert small.variant_key() != sub.variant_key()


def test_compiler_cache_roundtrip_warm_start(tmp_path):
    """save()/load() round-trips the LUT cache exactly: a restarted
    fleet's bring-up compiles are all served from cache."""
    path = tmp_path / "luts.json"
    pc = api.compiler()
    sub = api.substrate("cxl-tier-3", tokens_per_task=2)
    variants = [sub.engine_variant(i) for i in range(2)]
    model = sub.model_spec()
    T = sub.default_t_slice_ns(model)
    luts = pc.compile(variants, model, t_slice_ns=T, n_points=6)
    assert pc.stats()["builds"] == 1
    pc.save(path)

    pc2 = api.compiler()
    assert pc2.load(path) == 1
    again = pc2.compile(variants, model, t_slice_ns=T, n_points=6)
    assert pc2.stats()["builds"] == 0       # fully warm
    for key, lut in luts.items():
        assert again[key].entries == lut.entries    # exact round-trip
    # loading a missing file is a cold start, not an error
    assert api.compiler().load(tmp_path / "nope.json") == 0
