"""Tests for the repro.obs observability layer (DESIGN.md SS.8): tracer
span semantics and Chrome trace-event schema, metrics-registry bucket
boundaries and labeling, disabled-mode zero-cost contract, flight
recorder trigger/rotation, and the instrumented fleet end-to-end."""
import json
import threading

import pytest

from repro import obs
from repro.obs import (NULL_SPAN, FlightRecorder, MetricsRegistry, Tracer,
                       summarize_events)
from repro.obs.metrics import WAIT_SLICE_BUCKETS


@pytest.fixture(autouse=True)
def _fresh_obs():
    """Obs state is process-global on purpose; isolate every test."""
    obs.reset()
    yield
    obs.reset()


# -- tracer ------------------------------------------------------------------


def test_span_records_complete_event_with_args():
    tr = Tracer()
    with tr.span("work", cat="test", tid=7, k=1) as sp:
        sp.set("extra", "v")
    (ev,) = tr.events()
    assert ev["name"] == "work" and ev["ph"] == "X"
    assert ev["cat"] == "test" and ev["tid"] == 7
    assert ev["args"] == {"k": 1, "extra": "v"}
    assert ev["dur"] >= 0.0 and ev["ts"] >= 0.0


def test_span_nesting_inner_contained_in_outer():
    tr = Tracer()
    with tr.span("outer", tid=1):
        with tr.span("inner", tid=1):
            pass
    inner, outer = tr.events()       # inner exits (and records) first
    assert inner["name"] == "inner" and outer["name"] == "outer"
    # Perfetto nests slices by ts/dur containment on the same track
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6


def test_complete_is_posthoc_and_ordering_preserved():
    tr = Tracer()
    t0 = obs.now_ns()
    t1 = obs.now_ns()
    tr.complete("a", t0, t1, tid=3)
    tr.instant("marker", tid=3)
    evs = tr.events()
    assert [e["name"] for e in evs] == ["a", "marker"]
    assert evs[0]["ph"] == "X" and evs[1]["ph"] == "i"
    assert evs[1]["s"] == "t"        # thread-scoped instant
    assert evs[1]["ts"] >= evs[0]["ts"]


def test_chrome_schema_valid_and_json_serializable():
    tr = Tracer()
    tr.name_track(0, "engine-0")
    with tr.span("s", tid=0):
        pass
    tr.instant("i", tid=0)
    doc = json.loads(json.dumps(tr.to_chrome()))
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert meta and meta[0]["name"] == "thread_name"
    assert meta[0]["args"]["name"] == "engine-0"
    for ev in evs:
        assert {"name", "ph", "pid", "tid"} <= set(ev)
        if ev["ph"] == "X":
            assert isinstance(ev["ts"], float) and isinstance(
                ev["dur"], float)
            assert ev["dur"] >= 0.0


def test_tracer_export_and_summarize(tmp_path):
    tr = Tracer()
    for _ in range(3):
        with tr.span("hot"):
            pass
    with tr.span("cold"):
        pass
    path = tr.export(tmp_path / "sub" / "trace.json")
    doc = json.loads(path.read_text())
    rows = summarize_events(doc["traceEvents"])
    by_name = {r["name"]: r for r in rows}
    assert by_name["hot"]["count"] == 3 and by_name["cold"]["count"] == 1
    assert all(r["mean_us"] == pytest.approx(r["total_us"] / r["count"])
               for r in rows)


def test_tracer_thread_safety():
    tr = Tracer()

    def work():
        for _ in range(200):
            tr.complete("t", obs.now_ns(), obs.now_ns())

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr) == 800


# -- metrics registry --------------------------------------------------------


def test_histogram_bucket_boundaries_are_upper_bounds():
    reg = MetricsRegistry()
    # bounds (0,1,2,4,...): a value equal to a bound lands IN that bucket
    for v in (0.0, 1.0, 1.5, 4.0, 100.0):
        reg.observe("w", v, buckets=WAIT_SLICE_BUCKETS)
    h = reg.histogram("w")
    assert h.buckets == WAIT_SLICE_BUCKETS
    assert h.counts[0] == 1          # 0.0 <= 0
    assert h.counts[1] == 1          # 1.0 <= 1
    assert h.counts[2] == 1          # 1.5 <= 2
    assert h.counts[3] == 1          # 4.0 <= 4
    assert h.counts[-1] == 1         # 100.0 -> +inf overflow slot
    assert h.count == 5 and h.min == 0.0 and h.max == 100.0
    assert sum(h.counts) == h.count


def test_histogram_first_buckets_win_and_empty_requires_bounds():
    reg = MetricsRegistry()
    reg.observe("x", 1.0, buckets=(1.0, 2.0))
    reg.observe("x", 1.0, buckets=(9.0,))    # later bounds ignored
    assert reg.histogram("x").buckets == (1.0, 2.0)
    with pytest.raises(ValueError):
        obs.Histogram(())


def test_labeled_counters_are_distinct_and_formatted():
    reg = MetricsRegistry()
    reg.counter("admit", reason="ok", cls="default")
    reg.counter("admit", 2, reason="full", cls="default")
    reg.gauge("depth", 3.5, wid="0")
    assert reg.value("admit", reason="ok", cls="default") == 1
    assert reg.value("admit", reason="full", cls="default") == 2
    assert reg.value("admit") == 0            # unlabeled is a separate key
    snap = reg.as_dict()
    assert snap["counters"]["admit{cls=default,reason=full}"] == 2
    assert snap["gauges"]["depth{wid=0}"] == 3.5
    assert json.loads(json.dumps(snap)) == snap


# -- disabled-mode contract --------------------------------------------------


def test_disabled_mode_is_noop():
    assert not obs.enabled()
    assert obs.span("s") is NULL_SPAN         # shared singleton, no alloc
    assert obs.span("t", k=1) is obs.span("u")
    with obs.span("s") as sp:
        sp.set("k", "v")                      # chainable no-op
    obs.complete("c", obs.now_ns())
    obs.instant("i")
    obs.counter("n")
    obs.gauge("g", 1.0)
    obs.observe("h", 2.0)
    assert len(obs.tracer()) == 0
    snap = obs.metrics().as_dict()
    assert snap == {"counters": {}, "gauges": {}, "histograms": {}}


def test_enable_disable_roundtrip():
    obs.enable()
    assert obs.enabled()
    obs.counter("n")
    with obs.span("s"):
        pass
    assert obs.metrics().value("n") == 1 and len(obs.tracer()) == 1
    obs.disable()
    obs.counter("n")
    assert obs.metrics().value("n") == 1      # frozen while disabled
    obs.reset()
    assert len(obs.tracer()) == 0 and obs.flight_recorder() is None


# -- flight recorder ---------------------------------------------------------


def test_flight_recorder_ring_rotation():
    rec = FlightRecorder(capacity=4, miss_rate_threshold=None)
    for s in range(10):
        rec.record(s, {"depth": s})
    assert len(rec) == 4
    assert rec.slices() == [6, 7, 8, 9]       # oldest rotated out


def test_flight_recorder_triggers_once_per_episode(tmp_path):
    rec = FlightRecorder(capacity=8, miss_rate_threshold=0.5,
                         path=tmp_path / "flight.json")
    rec.record(0, {"depth": 1})
    assert rec.check(deadline_miss_rate=0.1) is None
    out = rec.check(deadline_miss_rate=0.9, context={"slice": 1})
    assert out is not None and out.exists()
    # still breaching: same episode, no second dump
    assert rec.check(deadline_miss_rate=0.95) is None
    assert rec.n_dumps == 1
    # recovery re-arms; next breach dumps to a numbered sibling file
    assert rec.check(deadline_miss_rate=0.0) is None
    out2 = rec.check(deadline_miss_rate=0.8)
    assert rec.n_dumps == 2
    assert out2.name == "flight.2.json" and out.exists() and out2.exists()
    payload = json.loads(out.read_text())
    assert payload["signals"]["deadline_miss_rate"] == 0.9
    assert payload["context"] == {"slice": 1}
    assert payload["frames"][0]["slice"] == 0


def test_flight_recorder_p99_trigger_and_in_memory_dump():
    rec = FlightRecorder(capacity=2, miss_rate_threshold=None,
                         p99_ms_threshold=5.0)
    rec.record(0, {})
    assert rec.check(p99_ms=1.0) is None
    assert rec.check(p99_ms=9.0) is None      # no path -> in-memory only
    assert rec.n_dumps == 1
    assert "p99_ms" in rec.last_dump["reason"]
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


# -- instrumented fleet end-to-end ------------------------------------------


def test_instrumented_fleet_run_produces_spans_and_metrics(tmp_path):
    from repro import api
    from repro.fleet import make_trace, summarize

    rec = FlightRecorder(capacity=16, miss_rate_threshold=0.0)
    obs.enable(flight_recorder=rec)
    tr = make_trace("mmpp", n_slices=12, seed=0)
    fleet = api.fleet("tpu-pool", n_engines=2, forecaster="ewma")
    s = summarize(fleet.run(tr))
    assert s.n_completed > 0

    names = {e["name"] for e in obs.tracer().events()}
    assert {"fleet.slice", "worker.step", "sched.slice"} <= names
    snap = obs.metrics().as_dict()
    admits = {k: v for k, v in snap["counters"].items()
              if k.startswith("fleet.admission")}
    assert sum(admits.values()) == s.n_submitted
    wait = obs.metrics().histogram("fleet.queue_wait_slices",
                                   cls="default", tenant="-")
    assert wait is not None and wait.count == s.n_completed

    # frames recorded every slice; miss_rate_threshold=0 always fires once
    assert len(rec) > 0 and rec.n_dumps >= 1
    assert {"engines", "running", "lut_cache"} <= set(rec.last_dump
                                                      ["frames"][0])

    paths = obs.export(trace_path=tmp_path / "trace.json",
                       metrics_path=tmp_path / "metrics.json")
    doc = json.loads(paths["trace"].read_text())
    tracks = {e["args"]["name"] for e in doc["traceEvents"]
              if e["ph"] == "M"}
    assert {"engine-0", "engine-1"} <= tracks
    assert json.loads(paths["metrics"].read_text()) == snap


def test_api_obs_facade():
    from repro import api

    assert api.obs() is obs
