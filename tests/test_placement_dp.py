"""Unit + property tests for the placement solvers (paper SS.III).

hypothesis is an optional dependency: without it only the property-based
tests are skipped; the deterministic DP/LUT tests still run.
"""
import itertools

import numpy as np
import pytest

from conftest import given, settings, st

from repro.core import spaces as sp
from repro.core.energy import EnergyModel, validate_placement
from repro.core.placement import (ClosedFormSolver, backtrace, build_lut,
                                  combine_clusters, dp_min_energy)
from repro.core.system import default_t_slice_ns


# ---------------------------------------------------------------------------
# Algorithm 1: verbatim DP vs exhaustive enumeration
# ---------------------------------------------------------------------------


def brute_force_min_energy(t_items, e_items, T, K):
    """Enumerate all x with sum(x)=K; returns min energy or inf."""
    n = len(t_items)
    best = float("inf")
    for x in itertools.product(range(K + 1), repeat=n):
        if sum(x) != K:
            continue
        if sum(xi * ti for xi, ti in zip(x, t_items)) <= T:
            best = min(best, sum(xi * ei for xi, ei in zip(x, e_items)))
    return best


@given(
    st.lists(st.integers(1, 6), min_size=1, max_size=3),
    st.data(),
)
@settings(max_examples=60, deadline=None)
def test_dp_matches_brute_force(t_items, data):
    n = len(t_items)
    e_items = data.draw(st.lists(
        st.floats(0.1, 50.0, allow_nan=False), min_size=n, max_size=n))
    K = data.draw(st.integers(0, 6))
    T = data.draw(st.integers(0, 30))
    dp, cnt = dp_min_energy(t_items, e_items, T, K)
    got = dp[n, T, K]
    want = brute_force_min_energy(t_items, e_items, T, K)
    if np.isinf(want):
        assert np.isinf(got)
    else:
        assert got == pytest.approx(want, rel=1e-12)


@given(st.integers(1, 5), st.integers(1, 5), st.integers(0, 8),
       st.integers(0, 40))
@settings(max_examples=60, deadline=None)
def test_dp_backtrace_is_consistent(t1, t2, K, T):
    """Backtraced x reproduces the DP objective and respects constraints."""
    t_items, e_items = [t1, t2], [3.0, 7.0]
    dp, cnt = dp_min_energy(t_items, e_items, T, K)
    if np.isinf(dp[2, T, K]):
        return
    x = backtrace(dp, cnt, t_items, T, K)
    assert sum(x) == K
    assert sum(xi * ti for xi, ti in zip(x, t_items)) <= T
    e = sum(xi * ei for xi, ei in zip(x, e_items))
    assert e == pytest.approx(dp[2, T, K], rel=1e-12)


def test_dp_monotone_in_time():
    """More time budget can never increase the optimal energy."""
    dp, _ = dp_min_energy([2, 5], [9.0, 1.0], 40, 6)
    final = dp[2, :, 6]
    assert np.all(np.diff(final[np.isfinite(final)]) <= 1e-12)
    # and once feasible, stays feasible
    feas = np.isfinite(final)
    first = int(np.argmax(feas))
    assert feas[first:].all()


def test_combine_clusters_small():
    """Algorithm 2 on hand-checkable tables."""
    # cluster A: space (t=1, e=10); cluster B: space (t=2, e=1); K=4, T=4
    dp_a, _ = dp_min_energy([1], [10.0], 4, 4)
    dp_b, _ = dp_min_energy([2], [1.0], 4, 4)
    min_e, k_opt = combine_clusters(dp_a[1], dp_b[1])
    # at T=4: B fits 2 items (t=4), A takes 2 (t=2<=4) -> e = 2*10 + 2*1 = 22
    assert min_e[4] == pytest.approx(22.0)
    assert k_opt[4] == 2
    # at T=1: A can do 1; B none -> k=4 infeasible
    assert np.isinf(min_e[1])
    assert k_opt[1] == -1
    # at T=8: all 4 in B -> e=4
    dp_a8, _ = dp_min_energy([1], [10.0], 8, 4)
    dp_b8, _ = dp_min_energy([2], [1.0], 8, 4)
    min_e8, k_opt8 = combine_clusters(dp_a8[1], dp_b8[1])
    assert min_e8[8] == pytest.approx(4.0)
    assert k_opt8[8] == 0


# ---------------------------------------------------------------------------
# Closed-form solver vs DP-grid exhaustive search with the FULL energy model
# ---------------------------------------------------------------------------


def full_model_brute_force(em, arch, K_weights, t_budget_ns, window_ns,
                           step):
    """Exhaustive search over placements on a coarse grid (4 spaces)."""
    names = [s.name for s in arch.spaces]
    best = float("inf")
    grid = list(range(0, K_weights + 1, step))
    if grid[-1] != K_weights:
        grid.append(K_weights)
    for x_hm in grid:
        for x_hs in grid:
            if x_hm + x_hs > K_weights:
                continue
            for x_lm in grid:
                x_ls = K_weights - x_hm - x_hs - x_lm
                if x_ls < 0:
                    continue
                pl = dict(zip(names, (x_hm, x_hs, x_lm, x_ls)))
                cost = em.task_cost(pl)
                if cost.t_task_ns > t_budget_ns + 1e-9:
                    continue
                over = False
                for s in arch.spaces:
                    if pl[s.name] > s.capacity_weights:
                        over = True
                if over:
                    continue
                e = cost.e_dyn_task_pj + em.static_energy_pj(
                    pl, window_ns, cost.t_cluster_ns)
                best = min(best, e)
    return best


@pytest.mark.parametrize("frac", [0.15, 0.3, 0.6, 1.0])
def test_closed_form_beats_or_matches_grid_search(frac):
    arch = sp.hh_pim()
    model = sp.ModelSpec("tiny", 240, 24_000, 0.8)
    em = EnergyModel(arch, model, rho=4.0)
    t_peak = em.task_cost(em.peak_placement(True)).t_task_ns
    t_budget = t_peak / frac if frac < 1 else t_peak * 1.0001
    solver = ClosedFormSolver(em, group=1)
    sols = {c.name: solver.solve_cluster(c, 240, t_budget, t_budget)
            for c in arch.clusters}
    tot = sols["hp"].energy_pj + sols["lp"].energy_pj[::-1]
    e_cf = float(np.min(tot))
    e_bf = full_model_brute_force(em, arch, 240, t_budget, t_budget, step=10)
    assert np.isfinite(e_cf)
    # closed-form is exact; the coarse grid search can only be >= optimal
    assert e_cf <= e_bf + 1e-6
    # and when the grid finds anything, closed-form is close to it
    if np.isfinite(e_bf):
        assert e_cf >= e_bf * 0.80


# ---------------------------------------------------------------------------
# LUT properties on the real benchmark models
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model", list(sp.TINYML_MODELS.values()),
                         ids=lambda m: m.name)
def test_lut_feasibility_and_validity(model):
    T = default_t_slice_ns(model, rho=4.0)
    lut = build_lut(sp.hh_pim(), model, t_slice_ns=T, n_points=24, rho=4.0)
    arch = sp.hh_pim()
    em = EnergyModel(arch, model, rho=4.0)
    feasible_seen = False
    for e in lut.entries:
        if not e.feasible:
            assert not feasible_seen, "feasibility must be monotone in t_c"
            continue
        feasible_seen = True
        validate_placement(arch, model, e.placement)
        # placement honors its own time constraint
        assert em.task_cost(e.placement).t_task_ns <= e.t_constraint_ns + 1e-6
    assert feasible_seen


@pytest.mark.parametrize("method", ["closed_form", "dp"])
def test_lut_methods_agree_where_statics_are_small(method):
    """In the peak region statics are negligible -> both objectives match."""
    model = sp.EFFICIENTNET_B0
    T = default_t_slice_ns(model, rho=4.0)
    lut = build_lut(sp.hh_pim(), model, t_slice_ns=T, n_points=24, rho=4.0,
                    method=method, k_groups=96)
    first = next(e for e in lut.entries if e.feasible)
    # peak-region placement must use both SRAMs (paper's green dot)
    assert first.placement.get("hp_sram", 0) > 0
    assert first.placement.get("lp_sram", 0) > 0


def test_lut_lookup_semantics():
    model = sp.MOBILENET_V2
    T = default_t_slice_ns(model, rho=4.0)
    lut = build_lut(sp.hh_pim(), model, t_slice_ns=T, n_points=16, rho=4.0)
    e = lut.lookup(T)
    assert e.feasible
    # lookup never returns an entry with a larger t_constraint than asked
    for t_q in np.linspace(lut.min_feasible_t_ns, T, 7):
        ent = lut.lookup(float(t_q))
        assert ent.t_constraint_ns <= t_q + 1e-6


def test_paper_fig6_placement_migration():
    """Fig. 6: placement migrates from SRAM-heavy to LP-MRAM-only as the
    constraint relaxes (benchmark default rho=4)."""
    model = sp.EFFICIENTNET_B0
    T = default_t_slice_ns(model, rho=4.0)
    lut = build_lut(sp.hh_pim(), model, t_slice_ns=T, n_points=64, rho=4.0)
    feas = [e for e in lut.entries if e.feasible]
    first, last = feas[0], feas[-1]
    assert first.placement["hp_sram"] > 0 and first.placement["lp_sram"] > 0
    assert last.placement["lp_mram"] == model.n_params  # LP-MRAM only
    # energy at the relaxed end is far below peak (paper: up to 43.17%
    # saving vs unoptimized allocation)
    assert last.e_task_pj < 0.75 * first.e_task_pj


def test_auto_resolution_respects_budget():
    """Paper SS.III.B: LUT build cost <= 1% of a time slice."""
    import time
    from repro.core.placement import auto_resolution
    model = sp.EFFICIENTNET_B0
    T = default_t_slice_ns(model, rho=4.0)
    n_points, k_groups = auto_resolution(model, T)
    assert n_points >= 8 and k_groups >= 8
    t0 = time.perf_counter()
    lut = build_lut(sp.hh_pim(), model, t_slice_ns=T, n_points=n_points,
                    rho=4.0, k_groups=k_groups)
    build_s = time.perf_counter() - t0
    assert any(e.feasible for e in lut.entries)
    # generous CI bound: within 100x of the budget on an arbitrary machine
    # (the budget constant is calibrated for the edge-class core)
    assert build_s < max(1.0, 100 * T * 0.01 / 1e9)


def test_auto_resolution_scales_with_slice():
    from repro.core.placement import auto_resolution
    small = auto_resolution(sp.EFFICIENTNET_B0, 1e6)    # 1 ms slice
    large = auto_resolution(sp.EFFICIENTNET_B0, 1e9)    # 1 s slice
    assert large[0] * large[1] >= small[0] * small[1]
