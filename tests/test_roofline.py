"""Roofline methodology validation.

XLA cost_analysis counts while bodies once (the reason the roofline uses an
analytic counter - see repro.launch.roofline). Here we validate the
analytic FLOPs against cost_analysis on configs compiled WITHOUT loops
(unrolled stacks, no microbatching, dense attention below the chunking
threshold), where cost_analysis is trustworthy.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.launch import roofline as rl
from repro.models import lm
from repro.models.common import ModelConfig


def _flops_of(f, *args):
    c = jax.jit(f).lower(*args).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca["flops"])


def _mk(name="v", family="dense", **kw):
    base = dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                vocab_size=1024, head_dim=32, dtype=jnp.float32,
                scan_layers=False, remat=False)
    base.update(kw)
    return ModelConfig(name=name, family=family, **base)


def test_xla_cost_analysis_undercounts_loops():
    """The motivating observation, pinned as a test."""
    def scanned(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None,
                            length=10)[0]

    def unrolled(x, w):
        for _ in range(10):
            x = x @ w
        return x

    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    f_scan = _flops_of(scanned, a, a)
    f_unroll = _flops_of(unrolled, a, a)
    assert f_unroll > 5 * f_scan     # 10x expected


@pytest.mark.parametrize("cfgkw, family", [
    (dict(), "dense"),
    (dict(n_experts=4, experts_per_token=2), "moe"),
])
def test_analytic_flops_match_xla_dense_path(cfgkw, family):
    cfg = _mk(family=family, **cfgkw)
    B, S = 2, 64
    params = jax.eval_shape(lambda k: lm.init_lm(k, cfg),
                            jax.random.PRNGKey(0))
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def fwd(p, t):
        h, _ = lm.forward_hidden(p, cfg, t)
        head = p["lm_head"].astype(cfg.dtype)
        return h @ head

    xla = _flops_of(fwd, params, tok)
    ours = (rl._matmul_flops_fwd(cfg, B, S) + rl._attn_flops_fwd(cfg, B, S)
            + rl._recurrent_flops_fwd(cfg, B, S))
    # dense attention (S < chunk threshold) computes the full rectangle as
    # does the analytic model; tolerance covers softmax/norm vector ops
    assert ours == pytest.approx(xla, rel=0.15)


def test_analytic_param_count_matches_init():
    from repro.configs import ARCH_IDS, get_config
    for arch in ARCH_IDS:
        cfg = dataclasses.replace(get_config(arch), scan_layers=False)
        params = jax.eval_shape(lambda k: lm.init_lm(k, cfg),
                                jax.random.PRNGKey(0))
        real = sum(x.size for x in jax.tree.leaves(params))
        # analytic count excludes norm scales / gate biases (tiny)
        analytic = rl.param_count(cfg)["total"]
        assert analytic == pytest.approx(real, rel=0.02), arch


def test_roofline_terms_positive_and_decode_memory_bound():
    cost = rl.decode_cost(_mk(), S=32768, B=128)
    assert cost.flops > 0 and cost.hbm_bytes > 0
    # decode is memory-bound at these shapes
    assert (cost.hbm_bytes / rl.HBM_BW) > (cost.flops / rl.PEAK_FLOPS)


def test_collective_parser_trip_multiplication():
    from repro.launch.hloparse import collective_bytes
    hlo = """
HloModule m

%body.1 (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[128,4]{1,0} all-reduce(f32[128,4]{1,0} %x), to_apply=%add
  ROOT %t = tuple()
}

%cond.1 (p: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %cmp = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %w = (s32[], f32[8]) while(%init), condition=%cond.1, body=%body.1
  %ag = bf16[64]{0} all-gather(bf16[32]{0} %y), dimensions={0}
  ROOT %r = f32[8] copy(%z)
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 4 * 4 * 12   # x12 trips
    assert out["all-gather"] == 64 * 2
