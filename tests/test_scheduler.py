"""Tests for the time-slice scheduler, energy model and system simulation."""
import pytest

from repro import api
from repro.core import spaces as sp
from repro.core import workloads
from repro.core.energy import EnergyModel
from repro.core.system import (default_t_slice_ns, energy_savings_table,
                               run_baseline, run_hh_pim)

RHO = 4.0


@pytest.fixture(scope="module")
def effnet_sched():
    m = sp.EFFICIENTNET_B0
    T = default_t_slice_ns(m, RHO)
    return api.scheduler("edge-hhpim", m, t_slice_ns=T, rho=RHO,
                         lut_points=32)


def test_scheduler_meets_2T_latency(effnet_sched):
    """Every slice's backlog (incl. movement) completes within T => the
    paper's <= 2T operational-latency guarantee holds."""
    for scen, tasks in workloads.SCENARIOS.items():
        m = sp.EFFICIENTNET_B0
        T = default_t_slice_ns(m, RHO)
        sched = api.scheduler("edge-hhpim", m, t_slice_ns=T, rho=RHO,
                              lut_points=32)
        for rep in sched.run(tasks):
            assert rep.deadline_met, (scen, rep.slice_idx)
            assert rep.t_exec_ns + rep.t_move_ns <= T + 1e-6


def test_scheduler_adapts_to_load(effnet_sched):
    """Low load => LP/MRAM-heavy placement; high load => SRAM-heavy."""
    m = sp.EFFICIENTNET_B0
    T = default_t_slice_ns(m, RHO)
    sched = api.scheduler("edge-hhpim", m, t_slice_ns=T, rho=RHO,
                          lut_points=32)
    hi = sched.step(10)
    lo = sched.step(1)
    hp_frac_hi = (hi.placement.get("hp_sram", 0)
                  + hi.placement.get("hp_mram", 0)) / m.n_params
    hp_frac_lo = (lo.placement.get("hp_sram", 0)
                  + lo.placement.get("hp_mram", 0)) / m.n_params
    assert hp_frac_hi > hp_frac_lo
    assert hi.energy_pj / 10 > lo.energy_pj / 1 * 0.0  # defined
    # per-task dynamic energy is lower at low load
    em = sched.em
    assert (em.task_cost(lo.placement).e_dyn_task_pj
            <= em.task_cost(hi.placement).e_dyn_task_pj + 1e-6)


def test_scheduler_movement_accounting(effnet_sched):
    m = sp.EFFICIENTNET_B0
    T = default_t_slice_ns(m, RHO)
    sched = api.scheduler("edge-hhpim", m, t_slice_ns=T, rho=RHO,
                          lut_points=32)
    sched.step(10)
    rep = sched.step(1)          # placement change => movement
    if rep.moved_weights:
        assert rep.t_move_ns > 0 and rep.e_move_pj > 0
    rep2 = sched.step(1)         # steady state => no movement
    assert rep2.moved_weights == 0
    assert rep2.t_move_ns == 0.0


def test_straggler_feedback_shifts_load():
    """A 2x slowdown of the LP pool must shrink its share (straggler
    mitigation via the placement LUT)."""
    m = sp.EFFICIENTNET_B0
    T = default_t_slice_ns(m, RHO)
    sched = api.scheduler("edge-hhpim", m, t_slice_ns=T, rho=RHO,
                          lut_points=32)
    normal = sched.step(5)
    lp_before = (normal.placement.get("lp_sram", 0)
                 + normal.placement.get("lp_mram", 0))
    sched.observe_slowdown("lp", 2.0)
    degraded = sched.step(5)
    lp_after = (degraded.placement.get("lp_sram", 0)
                + degraded.placement.get("lp_mram", 0))
    assert lp_after < lp_before
    assert degraded.deadline_met


def test_static_energy_volatility_rules():
    """SRAM holding weights burns static for the whole window; MRAM only
    while busy; empty cluster burns nothing."""
    m = sp.EFFICIENTNET_B0
    arch = sp.hh_pim()
    em = EnergyModel(arch, m, rho=RHO)
    T = 1e9  # 1 s window
    # all weights in LP-MRAM, zero busy time -> zero static (full gating)
    e_idle = em.static_energy_pj({"lp_mram": m.n_params}, T,
                                 {"hp": 0.0, "lp": 0.0})
    assert e_idle == 0.0
    # all weights in LP-SRAM, zero busy -> SRAM static * window
    e_sram = em.static_energy_pj({"lp_sram": m.n_params}, T,
                                 {"hp": 0.0, "lp": 0.0})
    want = sp.LP_SRAM.static_mw * 4 * T
    assert e_sram == pytest.approx(want)


def test_task_cost_parallel_clusters_serial_banks():
    m = sp.ModelSpec("t", 1000, 10_000, 1.0)
    arch = sp.hh_pim()
    em = EnergyModel(arch, m, rho=1.0)
    # all in one cluster: time adds across its MRAM+SRAM (serial)
    pl = {"hp_mram": 500, "hp_sram": 500}
    c = em.task_cost(pl)
    t_m = 500 * em.weight_time_ns(arch.cluster("hp").space("mram"))
    t_s = 500 * em.weight_time_ns(arch.cluster("hp").space("sram"))
    assert c.t_task_ns == pytest.approx(t_m + t_s)
    # split across clusters: time is the max (parallel)
    pl2 = {"hp_sram": 500, "lp_sram": 500}
    c2 = em.task_cost(pl2)
    t_hp = 500 * em.weight_time_ns(arch.cluster("hp").space("sram"))
    t_lp = 500 * em.weight_time_ns(arch.cluster("lp").space("sram"))
    assert c2.t_task_ns == pytest.approx(max(t_hp, t_lp))


def test_peak_sram_faster_than_mram_only():
    """Paper SS.IV.B: SRAM+MRAM-capable peak beats MRAM-only peak for every
    benchmark model (green vs purple dot)."""
    for m in sp.TINYML_MODELS.values():
        em = EnergyModel(sp.hh_pim(), m, rho=1.0)
        t_sram = em.task_cost(em.peak_placement(True)).t_task_ns
        t_mram = em.task_cost(em.peak_placement(False)).t_task_ns
        assert t_sram < t_mram


@pytest.mark.parametrize("model", [sp.EFFICIENTNET_B0, sp.RESNET_18],
                         ids=lambda m: m.name)
def test_hh_pim_saves_energy_in_all_scenarios(model):
    """Fig. 5's qualitative claim: HH-PIM beats every comparison arch in
    every scenario."""
    tab = energy_savings_table(model, rho=RHO, lut_points=24)
    for scen, row in tab.items():
        for kind in ("baseline", "hetero", "hybrid"):
            assert row[kind] > 0.0, (scen, kind, row)
    # Case 1 (low constant) is the best case; Case 2 (high constant) the
    # worst vs baseline - as in the paper.
    assert (tab["case1_low_constant"]["baseline"]
            > tab["case2_high_constant"]["baseline"])


def test_baseline_runs_and_misses_no_deadline_at_low_load():
    m = sp.EFFICIENTNET_B0
    res = run_baseline("baseline", m, "case1_low_constant", rho=RHO)
    assert res.deadline_miss == 0
    hh = run_hh_pim(m, "case1_low_constant", rho=RHO, lut_points=24)
    assert hh.deadline_miss == 0
    assert hh.energy_uj < res.energy_uj
