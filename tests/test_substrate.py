"""Substrate tests: the hardware-substrate registry (every registered
backend must build a LUT and schedule a slice), plus data, quant,
optimizers, compression, checkpointing.

hypothesis is an optional dependency: without it only the property-based
tests are skipped; the deterministic tests below still run.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import given, settings, st

from repro import api
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.optim.adamw import OptimizerConfig, make_optimizer
from repro.optim.compression import (compress_with_feedback,
                                     init_error_state)
from repro.quant.int8 import (dequantize, fake_quant, quantize_activations,
                              quantize_per_channel)


# -- substrate registry ------------------------------------------------------
# The in-repo mirror of the CI substrate-smoke job: every registered name
# (including gpu-pool/gpu-pool-mixed) must resolve a default workload,
# build a LUT with at least one feasible entry through its default solver,
# and run one scheduler slice with positive energy.


@pytest.mark.parametrize("name", api.list_substrates())
def test_registered_substrate_builds_lut_and_schedules(name):
    sub = api.substrate(name)
    model = sub.model_spec()
    t_slice_ns = sub.default_t_slice_ns(model)
    lut = sub.build_lut(model, t_slice_ns=t_slice_ns, n_points=6)
    assert any(e.feasible for e in lut.entries), name
    sched = api.scheduler(sub, model, t_slice_ns=t_slice_ns, lut_points=6)
    rep = sched.step(2)
    assert rep.n_tasks == 2 and rep.energy_pj > 0, name


@pytest.mark.parametrize("name", ("gpu-pool", "gpu-pool-mixed"))
def test_gpu_substrates_build_and_solve_with_dp(name):
    sub = api.substrate(name, tokens_per_task=2)
    model = sub.model_spec()
    t_slice_ns = sub.default_t_slice_ns(model)
    lut = sub.build_lut(model, t_slice_ns=t_slice_ns, n_points=6,
                        solver="dp")
    assert any(e.feasible for e in lut.entries), name
    entry = lut.lookup(t_slice_ns)
    assert sum(entry.placement.values()) == model.n_params


# -- data --------------------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b5a = d1.batch(5)
    b5b = d2.batch(5)                      # fresh pipeline, same step
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    assert b5a["tokens"].shape == (8, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(b5a["tokens"][:, 1:], b5a["labels"][:, :-1])


def test_data_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=8, seed=1)
    d = SyntheticLM(cfg)
    s0 = d.batch(0, shard=0, num_shards=4)
    s1 = d.batch(0, shard=1, num_shards=4)
    assert s0["tokens"].shape == (2, 8)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab_size=64, seq_len=64, global_batch=16,
                     structure=0.9)
    b = SyntheticLM(cfg).batch(0)
    # following the chain: most transitions deterministic => high repeat
    # rate of the most common bigram per position
    toks = b["tokens"]
    nxt = SyntheticLM(cfg)._next[toks[:, :-1]]
    agree = (nxt == toks[:, 1:]).mean()
    assert agree > 0.7


# -- int8 quant ---------------------------------------------------------------


@given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 10 ** 6))
@settings(max_examples=25, deadline=None)
def test_quant_roundtrip_bound(m, n, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1, (m, n)), jnp.float32)
    q, s = quantize_per_channel(w, axis=0)
    deq = dequantize(q, s, axis=0)
    # symmetric int8: error bounded by scale/2 per element
    bound = np.asarray(s)[None, :] * 0.5 + 1e-7
    assert np.all(np.abs(np.asarray(deq - w)) <= bound)


def test_activation_quant_shapes():
    x = jnp.asarray(np.random.default_rng(0).normal(0, 2, (5, 7)),
                    jnp.float32)
    q, s = quantize_activations(x)
    assert q.shape == (5, 7) and s.shape == (5,)
    deq = np.asarray(q, np.float32) * np.asarray(s)[:, None]
    err = np.abs(deq - np.asarray(x))
    assert err.max() <= float(s.max()) * 0.5 + 1e-7


def test_fake_quant_straight_through_grad():
    w = jnp.asarray(np.random.default_rng(1).normal(0, 1, (8, 8)),
                    jnp.float32)
    g = jax.grad(lambda w: (fake_quant(w) ** 2).sum())(w)
    # straight-through: gradient = 2 * fake_quant(w) exactly
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(fake_quant(w)),
                               rtol=1e-6)


# -- optimizers ---------------------------------------------------------------


@pytest.mark.parametrize("kind", ["adamw", "adamw_bf16", "adafactor"])
def test_optimizer_decreases_quadratic(kind):
    opt = make_optimizer(OptimizerConfig(kind=kind, lr=0.05,
                                         weight_decay=0.0, warmup_steps=1,
                                         total_steps=200))
    target = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (4, 4)),
                               jnp.float32), "b": jnp.ones((4,), jnp.float32)}
    params = jax.tree.map(jnp.zeros_like, target)
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2)
                   for a, b in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(target)))

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 0.2 * l0


def test_optimizer_state_structure_mirrors_params():
    opt = make_optimizer(OptimizerConfig(kind="adamw"))
    params = {"a": jnp.zeros((3, 3)), "n": {"b": jnp.zeros((2,))}}
    st_ = opt.init(params)
    assert jax.tree_util.tree_structure(st_["m"]) == \
        jax.tree_util.tree_structure(params)


# -- gradient compression -----------------------------------------------------


def test_compression_error_feedback_unbiased():
    """Constant gradient stream: with error feedback the cumulative applied
    update converges to the cumulative true gradient."""
    g = {"w": jnp.asarray([[0.33, -1.7], [2.4, 0.01]], jnp.float32)}
    err = init_error_state(g)
    applied = jnp.zeros_like(g["w"])
    for i in range(50):
        dec, err = compress_with_feedback(g, err)
        applied = applied + dec["w"]
    true = g["w"] * 50
    rel = float(jnp.max(jnp.abs(applied - true))) / float(
        jnp.max(jnp.abs(true)))
    assert rel < 0.02
    # error stays bounded (doesn't accumulate)
    assert float(jnp.max(jnp.abs(err["w"]))) < float(jnp.max(jnp.abs(
        g["w"])))


@given(st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_compression_single_step_error_bound(seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(0, 1, (6, 6)), jnp.float32)}
    err = init_error_state(g)
    dec, new_err = compress_with_feedback(g, err)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(new_err["w"]))) <= scale * 0.5 + 1e-9


# -- checkpointing ------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import ckpt
    tree = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "step": np.int32(7)}
    ckpt.save(tree, tmp_path, 7)
    out = ckpt.restore(tree, tmp_path)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert ckpt.latest_step(tmp_path) == 7


def test_checkpoint_atomic_keeps_previous(tmp_path):
    from repro.checkpoint import ckpt
    t1 = {"w": jnp.ones((2, 2))}
    ckpt.save(t1, tmp_path, 1)
    # a stale tmp dir from a crashed writer must not break anything
    (tmp_path / "step_00000002.tmp").mkdir()
    assert ckpt.latest_step(tmp_path) == 1
    out = ckpt.restore(t1, tmp_path)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((2, 2)))


def test_async_checkpointer(tmp_path):
    from repro.checkpoint import ckpt
    acp = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    tree = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3):
        acp.save_async(jax.tree.map(lambda x: x + s, tree), s)
    acp.wait()
    assert ckpt.latest_step(tmp_path) == 3
    out = ckpt.restore(tree, tmp_path, 3)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.full((4,), 3.0))
    # gc kept only 2
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2
    acp.close()
