"""TechModel + online DVFS controller tests (DESIGN.md SS.10).

Covers the per-tech-node physics (monotonicity of the energy scale in
clock, byte-identity with the legacy inline ``dvfs_energy_scale``
expression), DVFS bounds clamping, LUT byte-identity at the legacy
default clock for every DVFS-capable substrate, fleet-wide clock-grid
LUT dedupe, and determinism of the controller's per-slice solve.
"""
import pytest

from test_multipool import lut_digest

from repro import api
from repro.core.techmodel import (CLOCK_DECIMALS, TECH_MODELS,
                                  DVFSController, TechModel)

DVFS_SUBSTRATES = tuple(
    n for n in api.list_substrates()
    if api.substrate(n).tech_model() is not None)


# -- physics: vdd/freq curve + power model ----------------------------------


@pytest.mark.parametrize("name", sorted(TECH_MODELS))
def test_energy_scale_strictly_monotonic_in_clock(name):
    tm = api.tech_model(name)
    clocks = [0.05 + 0.95 * i / 40 for i in range(41)]
    es = [tm.energy_scale(c) for c in clocks]
    ps = [tm.power_scale(c) for c in clocks]
    ls = [tm.leakage_scale(c) for c in clocks]
    assert all(b > a for a, b in zip(es, es[1:])), name
    assert all(b > a for a, b in zip(ps, ps[1:])), name
    assert all(b > a for a, b in zip(ls, ls[1:])), name
    # V^2 at nominal rail is exactly 1: no hidden rescaling at full clock
    assert tm.energy_scale(1.0) == 1.0


def test_energy_scale_matches_legacy_inline_expression():
    """The registered models must reproduce the pre-TechModel
    ``V = V_MIN_FRAC + (1 - V_MIN_FRAC) * clock; V**2`` arithmetic
    bit-for-bit - this is what keeps every existing LUT byte-identical.
    """
    from repro.serve.gpu import TECH as GPU_TECH

    for tm in TECH_MODELS.values():
        for i in range(1, 101):
            c = i / 100
            v = tm.v_min_frac + (1.0 - tm.v_min_frac) * c
            assert tm.energy_scale(c) == v * v, (tm.name, c)
    # both serve modules still expose the historic callable, now routed
    # through the registered model
    from repro.serve import cxl, gpu
    assert gpu.dvfs_energy_scale(0.45) == GPU_TECH.energy_scale(0.45)
    assert cxl.dvfs_energy_scale(0.5) == cxl.TECH.energy_scale(0.5)
    assert gpu.V_MIN_FRAC == GPU_TECH.v_min_frac


def test_energy_scale_rejects_unphysical_clock():
    tm = api.tech_model("sm-pool-7nm")
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError):
            tm.energy_scale(bad)


# -- DVFS bounds -------------------------------------------------------------


def test_bounds_clamping():
    tm = api.tech_model("sm-pool-7nm")
    assert tm.clamp(0.01) == tm.dvfs_min
    assert tm.clamp(5.0) == tm.dvfs_max
    assert tm.clamp(0.6) == 0.6
    assert tm.in_bounds(tm.dvfs_min) and tm.in_bounds(tm.dvfs_max)
    assert not tm.in_bounds(tm.dvfs_min / 2)


def test_invalid_bounds_rejected_at_construction():
    with pytest.raises(ValueError):
        TechModel("bad", tech_nm=7, dvfs_min=0.8, dvfs_max=0.5)
    with pytest.raises(ValueError):
        TechModel("bad", tech_nm=7, dvfs_min=0.0)
    with pytest.raises(ValueError):
        TechModel("bad", tech_nm=7, v_min_frac=0.0)


def test_clock_grid_spans_bounds_and_merges_includes():
    tm = api.tech_model("sm-pool-7nm")
    grid = tm.clock_grid(5)
    assert grid[0] == tm.dvfs_min and grid[-1] == tm.dvfs_max
    assert list(grid) == sorted(grid) and len(set(grid)) == len(grid)
    # explicit points merge in (clamped), duplicates collapse at the
    # canonical rounding
    g2 = tm.clock_grid(5, include=(0.45, 0.45 + 10 ** -(CLOCK_DECIMALS
                                                        + 2), 0.01))
    assert 0.45 in g2 and g2[0] == tm.dvfs_min
    assert len(g2) == len(grid) + 1
    assert tm.clock_grid(1) == (tm.dvfs_max,)
    with pytest.raises(ValueError):
        tm.clock_grid(0)


# -- substrate axis: with_clock + byte-identity at the default clock --------


@pytest.mark.parametrize("name", DVFS_SUBSTRATES)
def test_with_clock_at_default_is_byte_identical(name):
    """Regression pin: threading the clock through the TechModel must
    not move a single LUT byte at the substrate's legacy default
    operating point (no silent physics drift)."""
    sub = api.substrate(name)
    clocked = sub.with_clock(sub.lp_clock)
    assert clocked.variant_key() == sub.variant_key()
    model = sub.model_spec()
    T = sub.default_t_slice_ns(model)
    a = sub.build_lut(model, t_slice_ns=T, n_points=6)
    b = clocked.build_lut(model, t_slice_ns=T, n_points=6)
    assert lut_digest(a) == lut_digest(b), name


@pytest.mark.parametrize("name", DVFS_SUBSTRATES)
def test_with_clock_clamps_and_rekeys(name):
    sub = api.substrate(name)
    tm = sub.tech_model()
    v = sub.with_clock(0.01)
    assert v.lp_clock == tm.dvfs_min
    assert v.variant_key() != sub.variant_key()


def test_with_clock_requires_a_dvfs_axis():
    with pytest.raises(ValueError):
        api.substrate("edge-hhpim").with_clock(0.5)
    assert api.substrate("edge-hhpim").tech_model() is None


def test_compile_clock_grid_builds_one_lut_per_point():
    pc = api.compiler()
    sub = api.substrate("gpu-pool")
    luts = pc.compile_clock_grid(sub, n_clocks=3)
    grid = sub.tech_model().clock_grid(3, include=(sub.lp_clock,))
    assert tuple(sorted(luts)) == grid
    assert pc.n_builds == len(grid)
    # a second compile of the same grid is served from cache
    pc.compile_clock_grid(sub, n_clocks=3)
    assert pc.n_builds == len(grid)
    with pytest.raises(ValueError):
        pc.compile_clock_grid(api.substrate("edge-hhpim"))


# -- the online controller ---------------------------------------------------


def test_controller_requires_techmodel_and_dynamic_solver():
    with pytest.raises(ValueError):
        api.scheduler("edge-hhpim", dvfs=True)
    with pytest.raises(ValueError):
        api.scheduler("gpu-pool", solver="fixed-hybrid", dvfs=True)


def test_controller_clocks_up_under_load():
    """The per-slice solve picks low clocks at light load (leakage-
    dominated) and the fastest point once the slice budget binds -
    deterministic fixed points for fixed inputs."""
    sched = api.scheduler("gpu-pool", dvfs=True)
    tm = api.substrate("gpu-pool").tech_model()
    clocks = [sched.step(n).clock for n in (1, 4, 16, 64)]
    assert all(c is not None and tm.in_bounds(c) for c in clocks)
    assert clocks == sorted(clocks)          # never clocks down as load grows
    assert clocks[-1] == tm.dvfs_max         # overload pins the fastest point
    assert clocks[0] < clocks[-1]            # light load runs slower


def test_scheduler_without_controller_reports_no_clock():
    rep = api.scheduler("gpu-pool").step(4)
    assert rep.clock is None


def test_controller_determinism_under_fixed_seed():
    from repro.fleet import make_trace, summarize

    def one_run():
        pc = api.compiler()
        trace = make_trace("mmpp", n_slices=12, seed=7)
        fleet = api.fleet("gpu-pool", n_engines=2, compiler=pc, dvfs=True)
        s = summarize(fleet.run(trace))
        clocks = [r.clock for w in fleet.workers for r in w.reports]
        return clocks, s.energy_uj, s.deadline_miss_rate

    c1, e1, m1 = one_run()
    c2, e2, m2 = one_run()
    assert c1 == c2 and e1 == e2 and m1 == m2
    assert any(c is not None for c in c1)


def test_fleet_shares_one_grid_of_luts_across_engines():
    """N same-shape engines with the controller pay one LUT build per
    clock grid point fleet-wide, exactly like the base builds."""
    pc = api.compiler()
    fleet = api.fleet("gpu-pool", n_engines=3, compiler=pc, dvfs=True)
    grid = fleet.workers[0].sched.dvfs.clocks
    assert pc.n_builds == len(grid)
    assert all(w.sched.dvfs is fleet.workers[0].sched.dvfs
               for w in fleet.workers[1:])


def test_controller_explicit_clocks_are_clamped_and_sorted():
    sub = api.substrate("gpu-pool")
    ctrl = DVFSController(sub, clocks=(0.9, 0.05, 0.5))
    tm = sub.tech_model()
    assert ctrl.clocks == (tm.dvfs_min, 0.5, 0.9)
    sel = ctrl.select(4)
    assert sel is not None and sel[0] in ctrl.clocks
