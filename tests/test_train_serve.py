"""Integration tests: trainer loop (+ resume, compression), decode engine,
and the HH-PIM hetero serving runtime."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.data.synthetic import DataConfig
from repro.models import lm
from repro.models.common import ModelConfig
from repro.models.hetero_linear import split_weight, tiered_matmul
from repro.optim.adamw import OptimizerConfig
from repro.serve.engine import DecodeEngine, Request
from repro.serve.hetero import HeteroServeEngine, tpu_arch
from repro.train.trainer import Trainer, TrainerConfig


def _tiny_cfg():
    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                       head_dim=16, dtype=jnp.float32, scan_layers=False,
                       remat=False)


def _tiny_trainer(tmp_path=None, steps=30, compression=False, seed=0):
    cfg = _tiny_cfg()
    return Trainer(
        cfg,
        OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=steps,
                        weight_decay=0.0),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                   seed=seed),
        TrainerConfig(steps=steps, ckpt_every=10,
                      ckpt_dir=str(tmp_path) if tmp_path else None,
                      grad_compression=compression))


def test_trainer_loss_decreases(tmp_path):
    out = _tiny_trainer(tmp_path).run()
    assert out["final_loss"] < out["first_loss"] * 0.9
    assert out["steps"] == 30


def test_trainer_resume_continuity(tmp_path):
    t1 = _tiny_trainer(tmp_path, steps=20)
    t1.run()
    t1._ckpt.wait()
    # new process-equivalent: fresh trainer resumes from step 20 checkpoint
    t2 = _tiny_trainer(tmp_path, steps=25)
    assert t2.maybe_resume()
    assert t2.step == 20
    out = t2.run()
    assert out["steps"] == 25


def test_trainer_preemption_stop(tmp_path):
    t = _tiny_trainer(tmp_path, steps=1000)
    orig_step = t._jit_step

    def stepper(*a, **k):
        if t.step >= 5:
            t.request_stop()
        return orig_step(*a, **k)

    t._jit_step = stepper
    out = t.run()
    assert out["steps"] <= 7      # stopped promptly
    t._ckpt.wait()
    from repro.checkpoint import ckpt
    assert ckpt.latest_step(tmp_path) == out["steps"]


def test_trainer_with_compression_converges(tmp_path):
    base = _tiny_trainer(None, steps=30, seed=1).run()
    comp = _tiny_trainer(None, steps=30, compression=True, seed=1).run()
    assert comp["final_loss"] < comp["first_loss"] * 0.9
    # compressed path tracks the uncompressed one loosely
    assert comp["final_loss"] < base["final_loss"] * 1.5 + 0.5


# -- serving ------------------------------------------------------------------


def test_decode_engine_serves_batched_requests():
    cfg = _tiny_cfg()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, max_batch=4, max_len=64)
    for r in range(6):
        eng.submit(Request(rid=r, prompt=[1 + r, 2, 3], max_new_tokens=5))
    eng.run_until_done()
    done = [r for r in eng.slots if r is not None] + eng.queue
    assert all(len(r.out) == 5 for r in done if r.done)
    assert sum(r.done for r in done) >= 4


def test_run_until_done_keeps_refilled_slot_completions():
    """A finished request whose slot is refilled from the queue must still
    be returned (the seed dropped it)."""
    cfg = _tiny_cfg()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    for r in range(6):
        eng.submit(Request(rid=r, prompt=[1 + r, 2, 3], max_new_tokens=3))
    done = eng.run_until_done()
    assert sorted(r.rid for r in done) == list(range(6))
    assert all(r.done and len(r.out) == 3 for r in done)


def test_run_until_done_returns_only_this_runs_completions():
    cfg = _tiny_cfg()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    eng.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=2))
    first = eng.run_until_done()
    assert [r.rid for r in first] == [0]
    eng.submit(Request(rid=1, prompt=[3, 4], max_new_tokens=2))
    second = eng.run_until_done()
    assert [r.rid for r in second] == [1]    # batch A not double-counted
    assert len(eng.drain_completed()) == 2   # accumulator holds both


def test_prefill_recurrent_and_local_state_uncontaminated():
    """Ragged refill waves must leave recurrent (rglru) state and
    local-attention ring buffers exactly as a token-by-token reference
    decode would - prompt grouping by exact length, no pad tokens."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("recurrentgemma_2b")   # rglru + local attention
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = [9, 4, 7]

    eng = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    eng.submit(Request(rid=0, prompt=list(prompt), max_new_tokens=1))
    eng.submit(Request(rid=1, prompt=[5, 6, 8, 2, 3], max_new_tokens=1))
    eng._fill_slots()                             # ragged wave: lengths 3, 5
    le, _ = lm.decode_step(params, cfg, eng._state, eng._toks,
                           jnp.asarray(eng._slot_pos))

    state = lm.init_decode_state(cfg, 1, 64)
    for t, tok in enumerate(prompt[:-1]):
        _, state = lm.decode_step(params, cfg, state,
                                  jnp.asarray([tok], jnp.int32),
                                  jnp.int32(t))
    lr, _ = lm.decode_step(params, cfg, state,
                           jnp.asarray([prompt[-1]], jnp.int32),
                           jnp.int32(len(prompt) - 1))
    np.testing.assert_allclose(np.asarray(le)[0], np.asarray(lr)[0],
                               atol=1e-4)


def test_drain_completed_clears_and_accumulates():
    cfg = _tiny_cfg()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, max_batch=4, max_len=64)
    for r in range(3):
        eng.submit(Request(rid=r, prompt=[1, 2], max_new_tokens=2))
    eng.run_until_done()
    drained = eng.drain_completed()
    assert len(drained) == 3
    assert eng.drain_completed() == []


def test_batched_prefill_handles_ragged_prompts():
    """Slot refill feeds prompts through one jitted prefill call; ragged
    prompt lengths in the same wave must still decode to completion."""
    cfg = _tiny_cfg()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, max_batch=4, max_len=64)
    prompts = [[5], [6, 7], [8, 9, 10, 11], [12, 13, 14]]
    for r, p in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=p, max_new_tokens=4))
    done = eng.run_until_done()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    assert all(len(r.out) == 4 for r in done)
    # one compiled prefill signature per distinct prompt length (exact
    # lengths - padding would corrupt recurrent/ring-buffer state)
    assert len(eng._prefill_fns) == 4


def test_refilled_slot_decodes_like_fresh_engine():
    """Per-slot decode positions: a request seated by slot refill (other
    slots already decoded past its positions) must see exactly the cache
    rows and next-step logits it would see in a fresh engine. Compared on
    logits with tolerance - token ids of a random-init model flip on
    near-tie argmax under run-to-run float jitter."""
    cfg = _tiny_cfg()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = [9, 4, 7]

    fresh = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    fresh.submit(Request(rid=0, prompt=list(prompt), max_new_tokens=4))
    fresh._fill_slots()

    eng = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    for r in range(2):
        eng.submit(Request(rid=r, prompt=[1 + r, 2], max_new_tokens=5))
    eng.submit(Request(rid=2, prompt=list(prompt), max_new_tokens=4))
    while not any(s is not None and s.done for s in eng.slots):
        eng.step()
    eng._fill_slots()          # seats rid=2 into a used slot
    slot = next(i for i, s in enumerate(eng.slots)
                if s is not None and s.rid == 2)
    assert eng._slot_pos[slot] == fresh._slot_pos[0]
    # the refilled slot's KV rows match a fresh engine's (junk from the
    # previous occupant is fully overwritten)
    for layer in ("tail_0", "tail_1"):
        np.testing.assert_allclose(
            np.asarray(eng._state["layers"][layer]["k"][slot]),
            np.asarray(fresh._state["layers"][layer]["k"][0]),
            atol=1e-5)
    # and the next decode step computes the same distribution
    lf, _ = lm.decode_step(params, cfg, fresh._state, fresh._toks,
                           jnp.asarray(fresh._slot_pos))
    le, _ = lm.decode_step(params, cfg, eng._state, eng._toks,
                           jnp.asarray(eng._slot_pos))
    np.testing.assert_allclose(np.asarray(le)[slot], np.asarray(lf)[0],
                               atol=1e-4)


def test_prefill_padding_is_inert():
    """Bucket padding must not change what a request conditions on: the
    engine's first-step logits for a non-power-of-two prompt equal an
    exact token-by-token reference decode."""
    cfg = _tiny_cfg()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = [9, 4, 7]              # L buckets to 4

    eng = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    eng.submit(Request(rid=0, prompt=list(prompt), max_new_tokens=1))
    eng._fill_slots()
    le, _ = lm.decode_step(params, cfg, eng._state, eng._toks,
                           jnp.asarray(eng._slot_pos))

    # reference: feed the prompt one token at a time, no padding
    state = lm.init_decode_state(cfg, 1, 64)
    for t, tok in enumerate(prompt[:-1]):
        _, state = lm.decode_step(params, cfg, state,
                                  jnp.asarray([tok], jnp.int32),
                                  jnp.int32(t))
    lr, _ = lm.decode_step(params, cfg, state,
                           jnp.asarray([prompt[-1]], jnp.int32),
                           jnp.int32(len(prompt) - 1))
    np.testing.assert_allclose(np.asarray(le)[0], np.asarray(lr)[0],
                               atol=1e-4)


def test_request_latency_accounting():
    cfg = _tiny_cfg()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, max_batch=2, max_len=64)
    for r in range(3):
        eng.submit(Request(rid=r, prompt=[1, 2, 3], max_new_tokens=2))
    done = eng.run_until_done()
    for r in done:
        assert r.t_submit is not None and r.t_done is not None
        assert r.t_submit <= r.t_start <= r.t_first_token <= r.t_done
        assert r.latency_s >= 0 and r.queue_wait_s >= 0
    assert eng.step_times_s and all(t > 0 for t in eng.step_times_s)


def test_tiered_matmul_matches_dense():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.5, (32, 64)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (4, 32)), jnp.float32)
    counts = {"hp_bf16": 16, "hp_int8": 16, "lp_bf16": 16, "lp_int8": 16}
    segs = split_weight(w, counts)
    y = tiered_matmul(x, segs)
    ref = x @ w
    # int8 segments introduce bounded quantization error
    rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    assert rel < 0.08


def test_tiered_matmul_custom_int8_tiers_matches_dense():
    """Substrate-declared tier plans (the cxl int8/int8 pairs and the
    3-way cxl-tier-3 split) flow through split_weight/tiered_matmul via
    the formats mapping."""
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(0, 0.5, (24, 48)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (3, 24)), jnp.float32)
    counts = {"hbm_int8": 20, "ddr_int8": 16, "cxl_int8": 12}
    formats = {t: "int8" for t in counts}
    segs = split_weight(w, counts, formats=formats)
    assert set(segs) == set(counts)
    assert all("q" in s for s in segs.values())     # all-int8 tiers
    y = tiered_matmul(x, segs)
    ref = x @ w
    rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    assert rel < 0.08
    # re-tiering = moving columns between int8 segments: same math
    moved = split_weight(w, {"hbm_int8": 4, "ddr_int8": 4, "cxl_int8": 40},
                         formats=formats)
    y2 = tiered_matmul(x, moved)
    rel2 = float(jnp.abs(y2 - ref).max() / jnp.abs(ref).max())
    assert rel2 < 0.08


def test_tiered_all_bf16_is_near_exact():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(0, 0.5, (16, 24)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (3, 16)), jnp.float32)
    segs = split_weight(w, {"hp_bf16": 12, "hp_int8": 0, "lp_bf16": 12,
                            "lp_int8": 0})
    y = tiered_matmul(x, segs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=3e-2,
                               atol=3e-2)   # bf16 rounding only


def test_hetero_engine_adapts_and_meets_deadlines():
    cfg = _tiny_cfg()
    params = lm.init_lm(jax.random.PRNGKey(1), cfg)
    eng = HeteroServeEngine(cfg, params, t_slice_ms=200.0, max_batch=4)
    hi = eng.run_slice(8)
    lo = eng.run_slice(1)
    lo2 = eng.run_slice(1)
    assert hi.report.deadline_met and lo.report.deadline_met
    # placement adapts: low load shifts weight share to the LP pool
    hp_hi = sum(v for k, v in hi.report.placement.items()
                if k.startswith("hp"))
    hp_lo = sum(v for k, v in lo2.report.placement.items()
                if k.startswith("hp"))
    assert hp_lo <= hp_hi
    # per-task energy lower at low load
    e_hi = hi.report.energy_pj / hi.report.n_tasks
    e_lo = lo2.report.energy_pj / lo2.report.n_tasks
    assert e_lo < e_hi * 1.5
    assert eng.energy_uj() > 0
    # the tiered weights actually changed format
    assert eng._tiered is not None
    x = jnp.ones((2, cfg.d_model), jnp.float32)
    y = eng.tiered_forward(x)
    assert y.shape == (2, cfg.d_ff)


def test_tpu_arch_spaces_sane():
    arch = tpu_arch(4, 4)
    names = {s.name for s in arch.spaces}
    assert names == {"hp_mram", "hp_sram", "lp_mram", "lp_sram"}
    hp_s = arch.cluster("hp").space("sram")
    hp_m = arch.cluster("hp").space("mram")
    # bf16 reads twice the bytes of int8
    assert hp_s.mem.read_ns == pytest.approx(2 * hp_m.mem.read_ns)
    # volatile bf16 residency pins idle power; int8 sleeps
    assert hp_s.mem.volatile and not hp_m.mem.volatile
    assert hp_s.mem.static_mw > hp_m.mem.static_mw
    # LP pool is slower per op
    lp = tpu_arch(4, 4).cluster("lp")
    assert lp.pe.op_ns > arch.cluster("hp").pe.op_ns
