"""Integration tests: trainer loop (+ resume, compression), decode engine,
and the HH-PIM hetero serving runtime."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.data.synthetic import DataConfig
from repro.models import lm
from repro.models.common import ModelConfig, reduced
from repro.models.hetero_linear import (fractions_to_counts, split_weight,
                                        tiered_matmul)
from repro.optim.adamw import OptimizerConfig
from repro.serve.engine import DecodeEngine, Request
from repro.serve.hetero import HeteroServeEngine, tpu_arch, tpu_model_spec
from repro.train.trainer import Trainer, TrainerConfig


def _tiny_cfg():
    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                       head_dim=16, dtype=jnp.float32, scan_layers=False,
                       remat=False)


def _tiny_trainer(tmp_path=None, steps=30, compression=False, seed=0):
    cfg = _tiny_cfg()
    return Trainer(
        cfg,
        OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=steps,
                        weight_decay=0.0),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                   seed=seed),
        TrainerConfig(steps=steps, ckpt_every=10,
                      ckpt_dir=str(tmp_path) if tmp_path else None,
                      grad_compression=compression))


def test_trainer_loss_decreases(tmp_path):
    out = _tiny_trainer(tmp_path).run()
    assert out["final_loss"] < out["first_loss"] * 0.9
    assert out["steps"] == 30


def test_trainer_resume_continuity(tmp_path):
    t1 = _tiny_trainer(tmp_path, steps=20)
    t1.run()
    t1._ckpt.wait()
    # new process-equivalent: fresh trainer resumes from step 20 checkpoint
    t2 = _tiny_trainer(tmp_path, steps=25)
    assert t2.maybe_resume()
    assert t2.step == 20
    out = t2.run()
    assert out["steps"] == 25


def test_trainer_preemption_stop(tmp_path):
    t = _tiny_trainer(tmp_path, steps=1000)
    orig_step = t._jit_step

    def stepper(*a, **k):
        if t.step >= 5:
            t.request_stop()
        return orig_step(*a, **k)

    t._jit_step = stepper
    out = t.run()
    assert out["steps"] <= 7      # stopped promptly
    t._ckpt.wait()
    from repro.checkpoint import ckpt
    assert ckpt.latest_step(tmp_path) == out["steps"]


def test_trainer_with_compression_converges(tmp_path):
    base = _tiny_trainer(None, steps=30, seed=1).run()
    comp = _tiny_trainer(None, steps=30, compression=True, seed=1).run()
    assert comp["final_loss"] < comp["first_loss"] * 0.9
    # compressed path tracks the uncompressed one loosely
    assert comp["final_loss"] < base["final_loss"] * 1.5 + 0.5


# -- serving -------------------------------------------------------------------


def test_decode_engine_serves_batched_requests():
    cfg = _tiny_cfg()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, max_batch=4, max_len=64)
    for r in range(6):
        eng.submit(Request(rid=r, prompt=[1 + r, 2, 3], max_new_tokens=5))
    eng.run_until_done()
    done = [r for r in eng.slots if r is not None] + eng.queue
    assert all(len(r.out) == 5 for r in done if r.done)
    assert sum(r.done for r in done) >= 4


def test_tiered_matmul_matches_dense():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.5, (32, 64)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (4, 32)), jnp.float32)
    counts = {"hp_bf16": 16, "hp_int8": 16, "lp_bf16": 16, "lp_int8": 16}
    segs = split_weight(w, counts)
    y = tiered_matmul(x, segs)
    ref = x @ w
    # int8 segments introduce bounded quantization error
    rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    assert rel < 0.08


def test_tiered_all_bf16_is_near_exact():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(0, 0.5, (16, 24)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (3, 16)), jnp.float32)
    segs = split_weight(w, {"hp_bf16": 12, "hp_int8": 0, "lp_bf16": 12,
                            "lp_int8": 0})
    y = tiered_matmul(x, segs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=3e-2,
                               atol=3e-2)   # bf16 rounding only


def test_hetero_engine_adapts_and_meets_deadlines():
    cfg = _tiny_cfg()
    params = lm.init_lm(jax.random.PRNGKey(1), cfg)
    eng = HeteroServeEngine(cfg, params, t_slice_ms=200.0, max_batch=4)
    hi = eng.run_slice(8)
    lo = eng.run_slice(1)
    lo2 = eng.run_slice(1)
    assert hi.report.deadline_met and lo.report.deadline_met
    # placement adapts: low load shifts weight share to the LP pool
    hp_hi = sum(v for k, v in hi.report.placement.items()
                if k.startswith("hp"))
    hp_lo = sum(v for k, v in lo2.report.placement.items()
                if k.startswith("hp"))
    assert hp_lo <= hp_hi
    # per-task energy lower at low load
    e_hi = hi.report.energy_pj / hi.report.n_tasks
    e_lo = lo2.report.energy_pj / lo2.report.n_tasks
    assert e_lo < e_hi * 1.5
    assert eng.energy_uj() > 0
    # the tiered weights actually changed format
    assert eng._tiered is not None
    x = jnp.ones((2, cfg.d_model), jnp.float32)
    y = eng.tiered_forward(x)
    assert y.shape == (2, cfg.d_ff)


def test_tpu_arch_spaces_sane():
    arch = tpu_arch(4, 4)
    names = {s.name for s in arch.spaces}
    assert names == {"hp_mram", "hp_sram", "lp_mram", "lp_sram"}
    hp_s = arch.cluster("hp").space("sram")
    hp_m = arch.cluster("hp").space("mram")
    # bf16 reads twice the bytes of int8
    assert hp_s.mem.read_ns == pytest.approx(2 * hp_m.mem.read_ns)
    # volatile bf16 residency pins idle power; int8 sleeps
    assert hp_s.mem.volatile and not hp_m.mem.volatile
    assert hp_s.mem.static_mw > hp_m.mem.static_mw
    # LP pool is slower per op
    lp = tpu_arch(4, 4).cluster("lp")
    assert lp.pe.op_ns > arch.cluster("hp").pe.op_ns
