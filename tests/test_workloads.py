"""Tests for the Fig. 4 workload generators and the scheduler's straggler
slowdown-factor rescaling path."""
import pytest

from repro import api
from repro.core import spaces as sp
from repro.core import workloads
from repro.core.energy import EnergyModel
from repro.core.system import default_t_slice_ns

RHO = 4.0


# -- the six case generators -------------------------------------------------


def test_all_cases_have_default_length_and_range():
    for name, tasks in workloads.SCENARIOS.items():
        assert len(tasks) == workloads.N_SLICES, name
        assert all(isinstance(t, int) for t in tasks), name
        assert all(1 <= t <= workloads.PEAK_TASKS for t in tasks), name


def test_case1_low_constant():
    assert workloads.case1_low_constant() == \
        [workloads.LOW_TASKS] * workloads.N_SLICES
    assert len(workloads.case1_low_constant(7)) == 7


def test_case2_high_constant():
    assert workloads.case2_high_constant() == \
        [workloads.PEAK_TASKS] * workloads.N_SLICES


def test_case3_periodic_spike_structure():
    tasks = workloads.case3_periodic_spike()
    for i, t in enumerate(tasks):
        want = (workloads.PEAK_TASKS if i % 10 < 2 else workloads.LOW_TASKS)
        assert t == want, i
    # exactly width peaks per full period
    assert sum(t == workloads.PEAK_TASKS for t in tasks[:10]) == 2


def test_case4_periodic_spike_frequent_structure():
    tasks = workloads.case4_periodic_spike_frequent()
    for i, t in enumerate(tasks):
        want = (workloads.PEAK_TASKS if i % 4 < 1 else workloads.LOW_TASKS)
        assert t == want, i


def test_case5_pulsing_alternates_half_periods():
    tasks = workloads.case5_pulsing()
    for i, t in enumerate(tasks):
        want = (workloads.PEAK_TASKS if (i // 5) % 2 == 0
                else workloads.LOW_TASKS)
        assert t == want, i
    # peak and low both actually occur
    assert workloads.PEAK_TASKS in tasks and workloads.LOW_TASKS in tasks


def test_case6_random_seeded_and_bounded():
    a = workloads.case6_random(seed=0)
    b = workloads.case6_random(seed=0)
    c = workloads.case6_random(seed=1)
    assert a == b
    assert a != c
    assert min(a) >= 1 and max(a) <= workloads.PEAK_TASKS


# -- straggler slowdown-factor rescaling -------------------------------------


def _sched():
    m = sp.EFFICIENTNET_B0
    T = default_t_slice_ns(m, RHO)
    return api.scheduler("edge-hhpim", m, t_slice_ns=T, rho=RHO,
                         lut_points=24)


def test_observe_slowdown_rejects_speedup():
    sched = _sched()
    with pytest.raises(ValueError):
        sched.observe_slowdown("lp", 0.5)


def test_slowdown_rescales_effective_weight_times():
    sched = _sched()
    lp_sram = sched.arch.cluster("lp").space("sram")
    hp_sram = sched.arch.cluster("hp").space("sram")
    t_lp = sched.em.weight_time_ns(lp_sram)
    t_hp = sched.em.weight_time_ns(hp_sram)
    sched.observe_slowdown("lp", 3.0)
    assert sched.em.weight_time_ns(lp_sram) == pytest.approx(3.0 * t_lp)
    # the other cluster's timing is untouched
    assert sched.em.weight_time_ns(hp_sram) == pytest.approx(t_hp)


def test_slowdown_rebuilds_and_caches_lut():
    sched = _sched()
    lut0 = sched.lut
    sched.observe_slowdown("lp", 2.0)
    lut2 = sched.lut
    assert lut2 is not lut0            # degraded timing => new LUT
    assert sched.lut is lut2           # cached per slowdown signature
    sched.observe_slowdown("lp", 1.0)
    assert sched.lut is lut0           # recovery reuses the original


def test_time_scale_in_energy_model_changes_task_cost():
    m = sp.EFFICIENTNET_B0
    em = EnergyModel(sp.hh_pim(), m, rho=RHO)
    em_slow = EnergyModel(sp.hh_pim(), m, rho=RHO,
                          time_scale={"lp": 2.0})
    pl = {"lp_sram": m.n_params}
    assert em_slow.task_cost(pl).t_task_ns == \
        pytest.approx(2.0 * em.task_cost(pl).t_task_ns)
    # energy per op is unaffected by a timing slowdown
    assert em_slow.task_cost(pl).e_dyn_task_pj == \
        pytest.approx(em.task_cost(pl).e_dyn_task_pj)
