"""Local approximation of the CI ``ruff format --check`` gate.

The dev containers this repo grows in do not ship ruff (PR 8 note in
CHANGES.md), so formatter drift could only be discovered after push.
This script re-implements the deterministic subset of the drift the
formatter (line-length 79, ``quote-style = "preserve"``) would flag, so
the lint job can be kept verifiably green from an offline checkout:

* trailing whitespace / whitespace-only lines (W291/W293),
* tabs and CRLF line endings,
* files not ending in exactly one newline,
* three or more consecutive blank lines (the formatter collapses them),
* top-level ``def``/``class`` not preceded by two blank lines,
* lines longer than 79 columns (the formatter's wrap surface - long
  lines are where ``ruff format --check`` diffs come from),
* missing space after a comma outside strings/comments (the formatter
  inserts one).

It is an approximation, not a replacement: CI still runs real ruff.
Run: ``python tools/check_format.py src tests benchmarks examples tools``
Exit status 1 when any file drifts; findings print as ``path:line: rule``.
"""
from __future__ import annotations

import io
import sys
import tokenize
from pathlib import Path

MAX_LEN = 79


def _comma_findings(source: str):
    """(line, col) of commas not followed by whitespace/closer, skipping
    string and comment tokens (tokenize gives exact spans)."""
    out = []
    lines = source.split("\n")
    try:
        toks = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return out                       # syntax problems belong to ruff check
    for tok in toks:
        if tok.type == tokenize.OP and tok.string == ",":
            row, col = tok.end
            line = lines[row - 1]
            if col < len(line) and line[col] not in " )]},":
                out.append((row, col))
    return out


def check_file(path: Path):
    findings = []
    raw = path.read_bytes()
    if b"\r" in raw:
        findings.append((0, "CRLF line ending"))
    text = raw.decode("utf-8")
    if text and not text.endswith("\n"):
        findings.append((0, "missing final newline"))
    elif text.endswith("\n\n"):
        findings.append((0, "blank line at end of file"))
    lines = text.split("\n")
    blanks = 0
    for i, ln in enumerate(lines, 1):
        if ln != ln.rstrip():
            findings.append((i, "trailing whitespace"))
        if "\t" in ln:
            findings.append((i, "tab character"))
        if len(ln) > MAX_LEN:
            findings.append((i, f"line too long ({len(ln)} > {MAX_LEN})"))
        if not ln.strip():
            blanks += 1
            if blanks == 3:
                findings.append((i, "more than two consecutive blank lines"))
        else:
            if (ln.startswith(("def ", "class ", "@"))
                    and i > 1 and 0 < blanks < 2
                    and not lines[i - 2 - blanks].startswith(("@", "#"))):
                findings.append(
                    (i, "expected two blank lines before top-level def"))
            blanks = 0
    for row, col in _comma_findings(text):
        findings.append((row, f"missing whitespace after comma (col {col})"))
    return findings


def main(argv=None) -> int:
    roots = (argv if argv is not None else sys.argv[1:]) or ["src", "tests"]
    n_bad = 0
    for root in roots:
        paths = ([Path(root)] if Path(root).suffix == ".py"
                 else sorted(Path(root).rglob("*.py")))
        for p in paths:
            for line, rule in check_file(p):
                print(f"{p}:{line}: {rule}")
                n_bad += 1
    if n_bad:
        print(f"format approximation: {n_bad} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
